//! Serving QoS plane integration: priority classes honored at batch
//! formation (strict effective priority with aging), per-key in-flight
//! caps (excess queued, never shed), deadline × priority composition,
//! the queue-depth autoscaler's resize events, and the load
//! generator's width-invariant determinism. Tensor planes run against
//! mock executors so no compiled artifacts are needed. CI runs this
//! file at both test-harness widths (see .github/workflows/ci.yml).

use engn::coordinator::{
    AutoscaleConfig, Backends, BatchConfig, Executor, InferenceService, JobError, Priority,
    QosConfig, ServiceConfig,
};
use engn::loadgen::{self, ArrivalProcess, LoadPlan, LoadgenConfig};
use engn::runtime::HostTensor;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn ok_tensor(n: usize) -> Result<HostTensor, String> {
    Ok(HostTensor::new(vec![1], vec![n as f32]))
}

/// Executor that logs each batch's artifact in execution order and
/// blocks until released (so tests can queue traffic behind a held
/// worker, then observe the exact order batch formation chose).
struct OrderLog {
    order: Arc<Mutex<Vec<String>>>,
    entered: Arc<AtomicUsize>,
    release: Arc<AtomicBool>,
}

impl Executor for OrderLog {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        self.order.lock().unwrap().push(artifact.to_string());
        self.entered.fetch_add(1, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        // A visible per-batch service time, so queue positions separate
        // cleanly in the latency percentiles.
        std::thread::sleep(Duration::from_millis(2));
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

struct OrderedService {
    svc: InferenceService,
    order: Arc<Mutex<Vec<String>>>,
    entered: Arc<AtomicUsize>,
    release: Arc<AtomicBool>,
}

fn ordered_service(qos: QosConfig) -> OrderedService {
    let order = Arc::new(Mutex::new(Vec::new()));
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let (o, e, r) = (order.clone(), entered.clone(), release.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Backends::tensor(Box::new(OrderLog {
                order: o.clone(),
                entered: e.clone(),
                release: r.clone(),
            })))
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            workers: 1,
            queue_capacity: 64,
            qos,
            ..Default::default()
        },
    );
    OrderedService { svc, order, entered, release }
}

/// Hold the single worker on a warm-up job so the queue builds, then
/// wait until it is genuinely inside the executor.
fn warm(h: &OrderedService) -> engn::coordinator::Ticket {
    let t = h.svc.submit_tensor("warm", vec![]).expect("accepted");
    let t0 = Instant::now();
    while h.entered.load(Ordering::SeqCst) == 0 && t0.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(h.entered.load(Ordering::SeqCst), 1, "worker never started");
    t
}

/// Interactive jobs submitted *after* a backlog of batch jobs are
/// still served first (strict priority, aging disabled), and their
/// p99 latency is strictly below the batch class's.
#[test]
fn interactive_beats_batch_under_contention() {
    let h = ordered_service(QosConfig {
        aging_step: Duration::ZERO,
        per_key_inflight: None,
    });
    let warm_ticket = warm(&h);
    let mut tickets = Vec::new();
    for _ in 0..6 {
        tickets.push(
            h.svc
                .submit_with_priority(tensor_payload("bulk"), Priority::Batch)
                .expect("accepted"),
        );
    }
    for _ in 0..3 {
        tickets.push(
            h.svc
                .submit_with_priority(tensor_payload("fast"), Priority::Interactive)
                .expect("accepted"),
        );
    }
    h.release.store(true, Ordering::SeqCst);
    warm_ticket.wait();
    for t in tickets {
        assert!(t.wait().result.is_ok());
    }
    let order = h.order.lock().unwrap().clone();
    assert_eq!(order[0], "warm");
    let first_bulk = order.iter().position(|a| a == "bulk").unwrap();
    let last_fast = order.iter().rposition(|a| a == "fast").unwrap();
    assert!(
        last_fast < first_bulk,
        "interactive must all run before batch: {order:?}"
    );
    let m = h.svc.metrics();
    let (int, bat) = (&m.per_priority[0], &m.per_priority[1]);
    assert_eq!(int.count, 3);
    assert_eq!(bat.count, 6);
    assert!(
        int.p99_latency_s < bat.p99_latency_s,
        "interactive p99 {} !< batch p99 {}",
        int.p99_latency_s,
        bat.p99_latency_s
    );
    h.svc.shutdown();
}

fn tensor_payload(artifact: &str) -> engn::coordinator::JobPayload {
    engn::coordinator::JobPayload::Tensor {
        artifact: artifact.to_string(),
        inputs: vec![],
    }
}

/// Anti-starvation: a best-effort job that has waited past the aging
/// horizon outranks interactive work submitted later (its effective
/// rank saturates at Interactive and its sequence number is older), so
/// scavenger traffic is never starved under sustained foreground load.
#[test]
fn aged_best_effort_is_not_starved_by_interactive_stream() {
    let h = ordered_service(QosConfig {
        aging_step: Duration::from_millis(5),
        per_key_inflight: None,
    });
    let warm_ticket = warm(&h);
    let scav = h
        .svc
        .submit_with_priority(tensor_payload("scav"), Priority::BestEffort)
        .expect("accepted");
    // Age past 2 steps: BestEffort (rank 2) reaches rank 0.
    std::thread::sleep(Duration::from_millis(25));
    let mut fast = Vec::new();
    for _ in 0..3 {
        fast.push(
            h.svc
                .submit_with_priority(tensor_payload("fast"), Priority::Interactive)
                .expect("accepted"),
        );
    }
    h.release.store(true, Ordering::SeqCst);
    warm_ticket.wait();
    assert!(scav.wait().result.is_ok());
    for t in fast {
        assert!(t.wait().result.is_ok());
    }
    let order = h.order.lock().unwrap().clone();
    assert_eq!(
        order[1], "scav",
        "aged best-effort must be served before fresh interactive: {order:?}"
    );
    h.svc.shutdown();
}

/// Executor recording the highest concurrent `execute_batch` overlap.
struct ConcurrencyProbe {
    inflight: Arc<AtomicUsize>,
    max_seen: Arc<AtomicUsize>,
    hold: Duration,
    rendezvous: usize,
}

impl Executor for ConcurrencyProbe {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        _artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        let now = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_seen.fetch_max(now, Ordering::SeqCst);
        let t0 = Instant::now();
        // With a rendezvous target, hold until that many executions
        // overlap (or time out) — proves the *absence* of a cap.
        while self.rendezvous > 1
            && self.max_seen.load(Ordering::SeqCst) < self.rendezvous
            && t0.elapsed() < Duration::from_millis(500)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(self.hold);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

fn probe_service(
    workers: usize,
    qos: QosConfig,
    hold: Duration,
    rendezvous: usize,
) -> (InferenceService, Arc<AtomicUsize>) {
    let inflight = Arc::new(AtomicUsize::new(0));
    let max_seen = Arc::new(AtomicUsize::new(0));
    let (infl, maxi) = (inflight.clone(), max_seen.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Backends::tensor(Box::new(ConcurrencyProbe {
                inflight: infl.clone(),
                max_seen: maxi.clone(),
                hold,
                rendezvous,
            })))
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            workers,
            queue_capacity: 64,
            qos,
            ..Default::default()
        },
    );
    (svc, max_seen)
}

/// With `per_key_inflight: Some(1)` and three workers, batches on one
/// hot key never overlap — and every capped job still completes
/// (queued, not shed). The uncapped control run proves the probe can
/// see overlap when the limiter is off.
#[test]
fn per_key_inflight_cap_is_never_exceeded() {
    // Control: no cap, rendezvous forces two workers to overlap.
    let (svc, max_seen) = probe_service(
        3,
        QosConfig::default(),
        Duration::from_millis(1),
        2,
    );
    let tickets: Vec<_> = (0..8)
        .map(|_| svc.submit_tensor("hot", vec![]).expect("accepted"))
        .collect();
    for t in tickets {
        assert!(t.wait().result.is_ok());
    }
    assert!(
        max_seen.load(Ordering::SeqCst) >= 2,
        "uncapped control never overlapped — probe is broken"
    );
    svc.shutdown();

    // Capped: the same traffic may never overlap on the key.
    let (svc, max_seen) = probe_service(
        3,
        QosConfig {
            per_key_inflight: Some(1),
            ..Default::default()
        },
        Duration::from_millis(2),
        1,
    );
    let tickets: Vec<_> = (0..10)
        .map(|_| svc.submit_tensor("hot", vec![]).expect("accepted"))
        .collect();
    for t in tickets {
        assert!(t.wait().result.is_ok(), "capped jobs must queue, not shed");
    }
    let m = svc.metrics();
    svc.shutdown();
    assert_eq!(max_seen.load(Ordering::SeqCst), 1, "cap exceeded");
    assert_eq!(m.max_inflight.get("tensor:hot"), Some(&1));
    assert_eq!(m.rejected, 0);
    assert_eq!(m.total_requests, 10);
}

/// Deadlines compose with priorities: an already-expired interactive
/// job is shed at formation (counted in its class), while batch work
/// and a generously-deadlined interactive job complete normally.
#[test]
fn deadline_shedding_composes_with_priorities() {
    let svc = InferenceService::start(
        || Ok(Backends::analytic()),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_capacity: 64,
            ..Default::default()
        },
    );
    let doomed = svc
        .submit_with_opts(
            engn::coordinator::JobPayload::Cost(engn::coordinator::CostJob::new(
                engn::baselines::PlatformId::CpuDgl,
                engn::model::GnnKind::Gcn,
                "CA",
            )),
            Priority::Interactive,
            Some(Duration::ZERO),
        )
        .expect("accepted");
    let ok_int = svc
        .submit_with_opts(
            engn::coordinator::JobPayload::Cost(engn::coordinator::CostJob::new(
                engn::baselines::PlatformId::GpuDgl,
                engn::model::GnnKind::Gcn,
                "CA",
            )),
            Priority::Interactive,
            Some(Duration::from_secs(5)),
        )
        .expect("accepted");
    let ok_batch = svc
        .submit_with_priority(
            engn::coordinator::JobPayload::Cost(engn::coordinator::CostJob::new(
                engn::baselines::PlatformId::Hygcn,
                engn::model::GnnKind::Gcn,
                "CA",
            )),
            Priority::Batch,
        )
        .expect("accepted");
    assert!(matches!(doomed.wait().result, Err(JobError::Expired)));
    assert!(ok_int.wait().result.is_ok());
    assert!(ok_batch.wait().result.is_ok());
    let m = svc.metrics();
    svc.shutdown();
    let (int, bat) = (&m.per_priority[0], &m.per_priority[1]);
    assert_eq!(int.expired, 1, "expiry must be attributed to the class");
    assert_eq!(int.count, 1);
    assert_eq!(bat.expired, 0);
    assert_eq!(bat.count, 1);
    assert_eq!(m.expired, 1);
}

/// The autoscaler scales up one worker at a time while the queue sits
/// above the high watermark, then back down once it drains — every
/// resize a ±1 step inside the configured bounds, timestamps
/// non-decreasing.
#[test]
fn autoscaler_scales_up_under_load_and_down_when_idle() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let order = Arc::new(Mutex::new(Vec::new()));
    let (o, e, r) = (order.clone(), entered.clone(), release.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Backends::tensor(Box::new(OrderLog {
                order: o.clone(),
                entered: e.clone(),
                release: r.clone(),
            })))
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            workers: 1,
            queue_capacity: 128,
            autoscale: Some(AutoscaleConfig {
                min_workers: 1,
                max_workers: 4,
                high_depth: 4,
                low_depth: 0,
                interval: Duration::from_millis(5),
                cooldown: Duration::from_millis(10),
            }),
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..24)
        .map(|_| svc.submit_tensor("a", vec![]).expect("accepted"))
        .collect();
    // Workers block in the executor, so the queue stays deep and the
    // supervisor steps the active count toward the max bound.
    let t0 = Instant::now();
    while svc.metrics().scale_events.is_empty() && t0.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(5));
    }
    release.store(true, Ordering::SeqCst);
    for t in tickets {
        assert!(t.wait().result.is_ok());
    }
    // Drained: depth 0 <= low watermark, so it steps back down.
    let t0 = Instant::now();
    while !svc
        .metrics()
        .scale_events
        .iter()
        .any(|ev| ev.to < ev.from)
        && t0.elapsed() < Duration::from_secs(2)
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    let m = svc.metrics();
    svc.shutdown();
    let events = &m.scale_events;
    assert!(
        events.iter().any(|ev| ev.to > ev.from),
        "never scaled up: {events:?}"
    );
    assert!(
        events.iter().any(|ev| ev.to < ev.from),
        "never scaled down: {events:?}"
    );
    for ev in events {
        assert!(ev.to >= 1 && ev.to <= 4, "resize out of bounds: {ev:?}");
        assert_eq!(
            ev.to.abs_diff(ev.from),
            1,
            "resizes must be single steps: {ev:?}"
        );
    }
    for pair in events.windows(2) {
        assert!(pair[0].at_s <= pair[1].at_s, "event times must be ordered");
    }
    assert!(m.active_workers >= 1 && m.active_workers <= 4);
}

/// Loadgen determinism: the plan is byte-identical at any pool width,
/// and driving it yields per-class offered counts that equal the
/// plan's — twice over, across fresh services.
#[test]
fn loadgen_plan_is_width_invariant_and_counts_are_deterministic() {
    let cfg = LoadgenConfig {
        seed: 9,
        requests: 60,
        arrivals: ArrivalProcess::Poisson { rate_rps: 4_000.0 },
        ..Default::default()
    };
    engn::util::pool::set_threads(1);
    let narrow = LoadPlan::build(&cfg).render_schedule();
    engn::util::pool::set_threads(8);
    let wide = LoadPlan::build(&cfg).render_schedule();
    engn::util::pool::set_threads(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    assert_eq!(narrow, wide, "plan must not depend on pool width");

    let plan = LoadPlan::build(&cfg);
    let counts = plan.priority_counts();
    assert_eq!(counts.iter().sum::<u64>(), 60);
    for round in 0..2 {
        let svc = InferenceService::start(
            || Ok(Backends::analytic()),
            ServiceConfig {
                workers: 2,
                queue_capacity: 256,
                ..Default::default()
            },
        );
        let report = loadgen::run(&svc, &plan);
        svc.shutdown();
        assert_eq!(report.plan_digest, plan.digest());
        for (i, stats) in report.per_priority.iter().enumerate() {
            assert_eq!(
                stats.offered, counts[i],
                "round {round}: class {} offered drifted",
                stats.priority
            );
        }
    }
}
