//! Out-of-core plane invariants (DESIGN.md §10): property tests pin
//! (1) the zero-spill identity — on a graph whose working set fits
//! HBM, the default `hbm4` hierarchy produces bit-identical reports to
//! the infinite-HBM `unbounded` preset under EVERY dataflow kind (the
//! memory plane is strictly additive), (2) the binary CSR format
//! round-trips graphs exactly — including relation-typed edges and
//! isolated vertices — and `PreparedGraph::from_csr` simulates
//! bit-identically to the in-memory prepare path, (3) chunked R-MAT
//! synthesis is pool-width-invariant all the way down to the persisted
//! CSR bytes, and (4) once a hierarchy does spill, sharding across
//! chips shrinks the worst chip's spill. CI runs this file at both
//! test-harness widths (see .github/workflows/ci.yml), like
//! dataflow_integration.

use engn::config::{AcceleratorConfig, DataflowKind};
use engn::graph::datasets::{self, DatasetGroup, DatasetSpec, ScalePolicy};
use engn::graph::io::{open_csr, save_csr};
use engn::graph::rmat::{self, RmatParams};
use engn::mem::MemHierarchy;
use engn::model::{GnnKind, GnnModel};
use engn::partition::{PartitionedGraph, PartitionerKind};
use engn::sim::{MultiChipSession, PreparedGraph, SimSession};
use engn::util::prop::prop_check;
use std::path::PathBuf;
use std::sync::Arc;

fn assert_reports_identical(a: &engn::sim::SimReport, b: &engn::sim::SimReport, ctx: &str) {
    assert_eq!(a.total_cycles(), b.total_cycles(), "{ctx}: cycles");
    assert_eq!(a.total_ops(), b.total_ops(), "{ctx}: ops");
    assert_eq!(a.chip_energy_j, b.chip_energy_j, "{ctx}: chip energy");
    assert_eq!(a.hbm_energy_j, b.hbm_energy_j, "{ctx}: hbm energy");
    assert_eq!(a.ext_energy_j, b.ext_energy_j, "{ctx}: ext energy");
    assert_eq!(a.power_w, b.power_w, "{ctx}: power");
    assert_eq!(a.layers.len(), b.layers.len(), "{ctx}: layer count");
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.q, lb.q, "{ctx}: layer {} Q", la.layer_idx);
        assert_eq!(la.total_cycles, lb.total_cycles, "{ctx}: layer {}", la.layer_idx);
        assert_eq!(la.spill, lb.spill, "{ctx}: layer {} spill", la.layer_idx);
    }
}

/// Scratch path for a CSR artifact, unique per (test, case).
fn scratch(tag: &str, case: u64) -> PathBuf {
    std::env::temp_dir().join(format!("engn_mem_it_{tag}_{case}.csr"))
}

/// Property (1): the zero-spill identity. Small R-MAT graphs fit the
/// 4 GB tier 0 with orders of magnitude to spare, so the default
/// `hbm4` stack must behave exactly like infinite HBM — same cycles,
/// same energy split, zero spill bytes/stalls — under every dataflow
/// kind, adaptive included. This is the guarantee that lets the mem
/// plane ship enabled by default without perturbing any existing
/// number.
#[test]
fn prop_zero_spill_identity_under_every_dataflow() {
    prop_check(4, 0x3E3_0001, |rng| {
        let n = rng.gen_usize(64, 1_200);
        let e = rng.gen_usize(n, 6 * n);
        let g = Arc::new(rmat::generate(n, e, RmatParams::default(), rng.next_u64()));
        let spec = datasets::by_code("PB").unwrap();
        let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
        let prepared = PreparedGraph::from_arc(g);
        for &kind in DataflowKind::all() {
            let mut bounded = AcceleratorConfig::engn();
            bounded.dataflow = kind;
            assert_eq!(bounded.mem, MemHierarchy::hbm4(), "hbm4 is the default");
            let mut infinite = bounded.clone();
            infinite.mem = MemHierarchy::unbounded();
            let a = SimSession::new(&bounded, &prepared, &model).run("PB");
            let b = SimSession::new(&infinite, &prepared, &model).run("PB");
            assert_reports_identical(&a, &b, kind.name());
            if a.spilled_bytes() != 0.0 || a.spill_stall_cycles() != 0.0 {
                return Err(format!("{}: resident graph spilled (n={n} e={e})", kind.name()));
            }
            if a.ext_energy_j != 0.0 {
                return Err(format!("{}: nonzero ext energy while resident", kind.name()));
            }
        }
        Ok(())
    });
}

/// Property (2a): CSR round-trip preserves the graph exactly — vertex
/// count, per-vertex out-neighbour multisets (the format groups by
/// source; order within a source is stable), and in/out degrees —
/// including graphs with isolated tail vertices.
#[test]
fn prop_csr_round_trip_preserves_graph() {
    prop_check(5, 0x3E3_0002, |rng| {
        let n = rng.gen_usize(10, 2_000);
        // Leave a tail of isolated vertices sometimes: edges only touch
        // the first `live` vertices but the header says `n`.
        let live = rng.gen_usize(n.div_ceil(2), n);
        let e = rng.gen_usize(1, 4 * live);
        let g = rmat::generate(live, e, RmatParams::default(), rng.next_u64());
        let g = engn::graph::Graph::from_edges(n, g.edges);
        let path = scratch("roundtrip", rng.next_u64());
        save_csr(&g, &path)?;
        let csr = open_csr(&path)?;
        let _ = std::fs::remove_file(&path);
        if csr.num_vertices != n || csr.num_edges() != e {
            return Err(format!("sizes: {}x{} vs {n}x{e}", csr.num_vertices, csr.num_edges()));
        }
        let h = csr.into_graph();
        let mut want: Vec<(u32, u32)> = g.edges.iter().map(|ed| (ed.src, ed.dst)).collect();
        let mut got: Vec<(u32, u32)> = h.edges.iter().map(|ed| (ed.src, ed.dst)).collect();
        want.sort_unstable();
        got.sort_unstable();
        if want != got {
            return Err(format!("edge multiset changed (n={n} live={live} e={e})"));
        }
        Ok(())
    });
}

/// Property (2b): a relation-typed graph (R-GCN) keeps its (src, dst,
/// relation) triples through the CSR file, and `from_csr` produces a
/// simulation bit-identical to the in-memory prepare path — same
/// degree ranking, same relation histogram, same report.
#[test]
fn csr_from_file_simulates_identically_with_relations() {
    let spec = datasets::by_code("AF").unwrap();
    assert!(spec.num_relations > 1, "AF is the R-GCN smoke dataset");
    let g = spec.instantiate(ScalePolicy::Capped, 0xE16A);
    let path = scratch("rgcn", 0);
    save_csr(&g, &path).expect("writing CSR");
    let csr = open_csr(&path).expect("reopening CSR");
    let _ = std::fs::remove_file(&path);
    assert_eq!(csr.num_relations, spec.num_relations);

    let model = GnnModel::for_dataset(GnnKind::Rgcn, &spec);
    let cfg = AcceleratorConfig::engn();
    let via_file = PreparedGraph::from_csr(csr);
    let in_memory = PreparedGraph::new(&g);
    assert_eq!(via_file.graph().num_vertices, in_memory.graph().num_vertices);
    assert_eq!(via_file.graph().num_edges(), in_memory.graph().num_edges());
    let a = SimSession::new(&cfg, &via_file, &model).run("AF");
    let b = SimSession::new(&cfg, &in_memory, &model).run("AF");
    assert_reports_identical(&a, &b, "AF via CSR");
}

/// Property (3): chunked synthesis is width-invariant all the way to
/// disk — the CSR files written from a 1-worker and an 8-worker
/// generation are byte-for-byte identical.
#[test]
fn chunked_synthesis_is_width_invariant_down_to_csr_bytes() {
    let serial = rmat::generate_chunked_with(1, 3_000, 24_000, RmatParams::default(), 0xC0FFEE, 1 << 12);
    let wide = rmat::generate_chunked_with(8, 3_000, 24_000, RmatParams::default(), 0xC0FFEE, 1 << 12);
    let pa = scratch("width1", 1);
    let pb = scratch("width8", 8);
    save_csr(&serial, &pa).expect("writing width-1 CSR");
    save_csr(&wide, &pb).expect("writing width-8 CSR");
    let ba = std::fs::read(&pa).expect("reading width-1 CSR");
    let bb = std::fs::read(&pb).expect("reading width-8 CSR");
    let _ = std::fs::remove_file(&pa);
    let _ = std::fs::remove_file(&pb);
    assert_eq!(ba, bb, "CSR bytes diverge with pool width");
    assert_eq!(serial.num_edges(), 24_000);
}

/// Property (4): once the hierarchy is small enough to spill, (a) the
/// stall and energy terms are strictly positive and the run is slower
/// than the resident baseline, and (b) sharding across 4 chips leaves
/// every chip with less spill than the single chip had — scale-out is
/// the other way out of the spill regime.
#[test]
fn spilling_costs_and_sharding_recovers() {
    let spec = DatasetSpec {
        code: "OOC",
        name: "mem-integration",
        vertices: 6_000,
        edges: 90_000,
        feature_dim: 512,
        labels: 16,
        num_relations: 1,
        group: DatasetGroup::Synthetic,
    };
    let g = Arc::new(rmat::generate(spec.vertices, spec.edges, RmatParams::default(), 0xBEEF));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let mut cfg = AcceleratorConfig::engn();
    cfg.mem.name = "tiny";
    // ~12 MB in-features: cap tier 0 well below that.
    cfg.mem.tiers[0].capacity_bytes = 1024.0 * 1024.0;

    let prepared = PreparedGraph::from_arc(g.clone());
    let single = SimSession::new(&cfg, &prepared, &model).run(spec.code);
    let resident = SimSession::new(
        &AcceleratorConfig::engn().with_mem(MemHierarchy::unbounded()),
        &prepared,
        &model,
    )
    .run(spec.code);
    assert!(single.spilled_bytes() > 0.0, "tiny tier 0 must spill");
    assert!(single.spill_stall_cycles() > 0.0);
    assert!(single.ext_energy_j > 0.0);
    assert!(single.total_cycles() > resident.total_cycles(), "spill must cost cycles");
    assert!(single.energy_j() > resident.energy_j(), "spill must cost energy");

    let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 4);
    let multi = MultiChipSession::new(&cfg, &parts, &model).run(spec.code);
    // Worst chip, not the sum: halo replication can inflate aggregate
    // bytes across chips, but each chip's own working set must shrink.
    let worst = multi
        .per_chip
        .iter()
        .map(engn::sim::SimReport::spilled_bytes)
        .fold(0.0f64, f64::max);
    assert!(
        worst < single.spilled_bytes(),
        "worst per-chip spill {worst} vs single {}",
        single.spilled_bytes()
    );
}
