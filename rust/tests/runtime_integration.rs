//! Integration: the full AOT bridge — JAX/Pallas → HLO text →
//! `HloModuleProto::from_text_file` → PJRT compile → execute — checked
//! numerically against a hand-rolled Rust reference implementation of the
//! GCN math, and end-to-end through the serving coordinator.
//!
//! Requires `make artifacts` (skips gracefully if missing so `cargo test`
//! works in a fresh checkout).

use engn::coordinator::{Backends, BatchConfig, InferenceService};
use engn::runtime::{HostTensor, Runtime};
use engn::util::prop::assert_allclose;
use engn::util::rng::Xoshiro256StarStar;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn rand_tensor(rng: &mut Xoshiro256StarStar, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    HostTensor::new(shape.to_vec(), data)
}

/// Reference GCN forward: relu(A @ (relu(A @ (X W1)) W2)), row-major.
fn ref_gcn(a: &HostTensor, x: &HostTensor, w1: &HostTensor, w2: &HostTensor) -> Vec<f32> {
    let layer = |a: &HostTensor, x: &[f32], xn: usize, xf: usize, w: &HostTensor| -> Vec<f32> {
        let h = w.shape[1];
        // xw = x @ w
        let mut xw = vec![0.0f32; xn * h];
        for i in 0..xn {
            for k in 0..xf {
                let xv = x[i * xf + k];
                if xv != 0.0 {
                    for j in 0..h {
                        xw[i * h + j] += xv * w.data[k * h + j];
                    }
                }
            }
        }
        // out = relu(a @ xw)
        let n = a.shape[0];
        let mut out = vec![0.0f32; n * h];
        for i in 0..n {
            for k in 0..xn {
                let av = a.data[i * xn + k];
                if av != 0.0 {
                    for j in 0..h {
                        out[i * h + j] += av * xw[k * h + j];
                    }
                }
            }
        }
        out.iter_mut().for_each(|v| *v = v.max(0.0));
        out
    };
    let h1 = layer(a, &x.data, x.shape[0], x.shape[1], w1);
    layer(a, &h1, x.shape[0], w1.shape[1], w2)
}

#[test]
fn tiny_gcn_matches_rust_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_only(&dir, &["gcn_tiny"]).expect("load gcn_tiny");
    assert!(["cpu", "host"].contains(&rt.platform().to_lowercase().as_str()));
    let spec = rt.spec("gcn_tiny").unwrap().clone();
    let mut rng = Xoshiro256StarStar::seed_from_u64(42);
    // Build a small normalized-ish adjacency (entries in [0, 0.5]) and
    // random features/weights.
    let mut a = rand_tensor(&mut rng, &spec.inputs[0]);
    a.data.iter_mut().for_each(|v| *v = (v.abs()) * 0.5);
    let x = rand_tensor(&mut rng, &spec.inputs[1]);
    let w1 = rand_tensor(&mut rng, &spec.inputs[2]);
    let w2 = rand_tensor(&mut rng, &spec.inputs[3]);

    let got = rt
        .execute("gcn_tiny", &[a.clone(), x.clone(), w1.clone(), w2.clone()])
        .expect("execute");
    let want = ref_gcn(&a, &x, &w1, &w2);
    assert_eq!(got.shape, spec.outputs[0]);
    assert_allclose(&got.data, &want, 1e-4, 1e-4).expect("numerics");
}

#[test]
fn execute_validates_shapes_and_names() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_only(&dir, &["gcn_tiny"]).expect("load");
    let err = rt.execute("nonexistent", &[]).unwrap_err();
    assert!(err.contains("unknown artifact"), "{err}");
    let bad = vec![HostTensor::zeros(vec![3, 3])];
    let err = rt.execute("gcn_tiny", &bad).unwrap_err();
    assert!(err.contains("expected"), "{err}");
}

#[test]
fn repeated_executions_are_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_only(&dir, &["gcn_tiny"]).expect("load");
    let spec = rt.spec("gcn_tiny").unwrap().clone();
    let mut rng = Xoshiro256StarStar::seed_from_u64(7);
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| rand_tensor(&mut rng, s))
        .collect();
    let a = rt.execute("gcn_tiny", &inputs).unwrap();
    let b = rt.execute("gcn_tiny", &inputs).unwrap();
    assert_eq!(a.data, b.data);
    assert_eq!(rt.executions(), 2);
}

/// `execute_batch` must agree with per-request `execute` whatever path
/// it takes: `gcn_tiny` is compiled without a leading batch dimension,
/// so the stacked dispatch is rejected and the runtime falls back to
/// individual executions — transparently to the caller.
#[test]
fn execute_batch_matches_individual_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_only(&dir, &["gcn_tiny"]).expect("load");
    let spec = rt.spec("gcn_tiny").unwrap().clone();
    let mut rng = Xoshiro256StarStar::seed_from_u64(19);
    let mut make_inputs = || -> Vec<HostTensor> {
        spec.inputs
            .iter()
            .map(|s| rand_tensor(&mut rng, s))
            .collect()
    };
    let batches = vec![make_inputs(), make_inputs(), make_inputs()];
    let results = rt.execute_batch("gcn_tiny", &batches);
    assert_eq!(results.len(), 3);
    for (inputs, result) in batches.iter().zip(&results) {
        let batched = result.as_ref().expect("batched execution ok");
        let single = rt.execute("gcn_tiny", inputs).expect("single execution ok");
        assert_eq!(batched.shape, single.shape);
        assert_eq!(batched.data, single.data);
    }
}

#[test]
fn serving_coordinator_end_to_end_over_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    // The runtime is built inside the worker thread (PJRT is !Send).
    let svc = InferenceService::start(
        move || Runtime::load_only(&dir, &["gcn_tiny"]).map(|rt| Backends::tensor(Box::new(rt))),
        BatchConfig::default(),
    );
    let mut rng = Xoshiro256StarStar::seed_from_u64(3);
    let shapes = [vec![8, 8], vec![8, 4], vec![4, 3], vec![3, 2]];
    let mut tickets = Vec::new();
    for _ in 0..6 {
        let inputs: Vec<HostTensor> = shapes.iter().map(|s| rand_tensor(&mut rng, s)).collect();
        tickets.push(svc.submit_tensor("gcn_tiny", inputs).expect("intake accepts"));
    }
    for ticket in tickets {
        let out = ticket.wait().into_tensor().expect("inference ok");
        assert_eq!(out.shape, vec![8, 2]);
        assert!(out.data.iter().all(|v| v.is_finite()));
    }
    let m = svc.metrics();
    assert_eq!(m.total_requests, 6);
    assert!(m.per_key["tensor:gcn_tiny"].mean_exec_s > 0.0);
    svc.shutdown();
}
