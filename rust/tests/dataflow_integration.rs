//! Per-layer dataflow planning invariants (DESIGN.md §9): property
//! tests pin (1) fixed-kind planning is stable — the one-shot
//! `Simulator` path, the `SimSession` path and repeated runs agree
//! bit-identically for every fixed dataflow, at any sweep width,
//! (2) the adaptive planner never totals more cycles than ANY fixed
//! dataflow — on seeded R-MAT graphs and on every Table-5 suite pair,
//! (3) parse/name round-trips for all kinds and the sampling-
//! extrapolation contract of the two sparse dataflows. CI runs this
//! file at both test-harness widths (see .github/workflows/ci.yml),
//! like partition_integration.

use engn::config::{AcceleratorConfig, DataflowKind};
use engn::graph::datasets::ScalePolicy;
use engn::graph::rmat::{self, RmatParams};
use engn::graph::Edge;
use engn::model::{GnnKind, GnnModel};
use engn::report::experiments::Eval;
use engn::sim::dataflow::{self, TileView};
use engn::sim::{sweep_with, PreparedGraph, SimSession, Simulator};
use engn::util::prop::prop_check;
use std::sync::Arc;

fn assert_reports_identical(a: &engn::sim::SimReport, b: &engn::sim::SimReport, ctx: &str) {
    assert_eq!(a.total_cycles(), b.total_cycles(), "{ctx}: cycles");
    assert_eq!(a.total_ops(), b.total_ops(), "{ctx}: ops");
    assert_eq!(a.chip_energy_j, b.chip_energy_j, "{ctx}: chip energy");
    assert_eq!(a.hbm_energy_j, b.hbm_energy_j, "{ctx}: hbm energy");
    assert_eq!(a.power_w, b.power_w, "{ctx}: power");
    assert_eq!(a.davc().accesses, b.davc().accesses, "{ctx}: davc accesses");
    assert_eq!(a.davc().hits, b.davc().hits, "{ctx}: davc hits");
    assert_eq!(a.layers.len(), b.layers.len(), "{ctx}: layer count");
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.q, lb.q, "{ctx}: layer {} Q", la.layer_idx);
        assert_eq!(la.total_cycles, lb.total_cycles, "{ctx}: layer {}", la.layer_idx);
        assert_eq!(la.traffic.hbm_read_bytes, lb.traffic.hbm_read_bytes, "{ctx}");
        assert_eq!(la.traffic.hbm_write_bytes, lb.traffic.hbm_write_bytes, "{ctx}");
    }
}

/// Property (1a): every fixed kind plans every layer to itself (no
/// selection record), and the one-shot `Simulator` wrapper reproduces
/// the `SimSession` report bit-identically — the refactor moved the
/// dataflow decision into the plan without changing fixed-kind output.
#[test]
fn prop_fixed_kinds_plan_uniformly_and_paths_agree() {
    prop_check(6, 0xDF_0001, |rng| {
        let n = rng.gen_usize(64, 1_500);
        let e = rng.gen_usize(n, 6 * n);
        let g = Arc::new(rmat::generate(n, e, RmatParams::default(), rng.next_u64()));
        let spec = engn::graph::datasets::by_code("PB").unwrap();
        let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
        let prepared = PreparedGraph::from_arc(g.clone());
        for &kind in DataflowKind::fixed() {
            let mut cfg = AcceleratorConfig::engn();
            cfg.dataflow = kind;
            let session = SimSession::new(&cfg, &prepared, &model);
            for p in session.plan() {
                if p.dataflow != kind || p.selection.is_some() {
                    return Err(format!("{}: layer not planned to itself", kind.name()));
                }
            }
            let a = session.run("PB");
            let b = Simulator::new(cfg.clone()).run(&model, &g, "PB");
            let c = session.run("PB");
            assert_reports_identical(&a, &b, kind.name());
            assert_reports_identical(&a, &c, kind.name());
        }
        Ok(())
    });
}

/// Property (1b): a sweep over one config per kind (adaptive included)
/// is bit-identical serial vs parallel — per-layer planning keeps the
/// scratch-buffer reuse (DAVC, ring tile scratch) thread-confined.
#[test]
fn sweep_width_does_not_change_any_dataflow_report() {
    let spec = engn::graph::datasets::by_code("PB").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(8), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let prepared = PreparedGraph::from_arc(g);
    let variants: Vec<AcceleratorConfig> = DataflowKind::all()
        .iter()
        .map(|&df| {
            let mut cfg = AcceleratorConfig::engn().named(&format!("EnGN_{}", df.name()));
            cfg.dataflow = df;
            cfg
        })
        .collect();
    let serial = sweep_with(1, &variants, &prepared, &model, "PB");
    let parallel = sweep_with(8, &variants, &prepared, &model, "PB");
    assert_eq!(serial.len(), variants.len());
    for ((cfg, a), b) in variants.iter().zip(&serial).zip(&parallel) {
        assert_reports_identical(a, b, &cfg.name);
    }
}

/// Property (2a): on seeded R-MAT graphs the adaptive planner's total
/// cycles never exceed any fixed dataflow's. Exact `<=` is safe: the
/// planner picks the per-layer argmin of the executor's own charges,
/// layer costs are independent, and termwise-`<=` float sums stay `<=`.
#[test]
fn prop_adaptive_never_loses_on_rmat() {
    prop_check(6, 0xDF_0002, |rng| {
        let n = rng.gen_usize(64, 1_500);
        let e = rng.gen_usize(n, 6 * n);
        let g = Arc::new(rmat::generate(n, e, RmatParams::default(), rng.next_u64()));
        let spec = engn::graph::datasets::by_code("PB").unwrap();
        let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
        let prepared = PreparedGraph::from_arc(g);
        let mut cfg = AcceleratorConfig::engn();
        cfg.dataflow = DataflowKind::Adaptive;
        let session = SimSession::new(&cfg, &prepared, &model);
        for p in session.plan() {
            if p.dataflow == DataflowKind::Adaptive {
                return Err("a layer stayed Adaptive after planning".into());
            }
            let Some(sel) = &p.selection else {
                return Err("adaptive layer lost its selection record".into());
            };
            // The charge pass runs over the estimate shortlist — a
            // non-empty canonical-order subset of the fixed kinds that
            // always contains the pick.
            if sel.measured.is_empty()
                || sel.measured.len() > DataflowKind::fixed().len()
                || sel.why.is_empty()
            {
                return Err("selection record incomplete".into());
            }
            if !sel.measured.iter().any(|&(k, _)| k == p.dataflow) {
                return Err("picked kind missing from measured shortlist".into());
            }
        }
        let adaptive = session.run("PB").total_cycles();
        for &kind in DataflowKind::fixed() {
            let mut fixed_cfg = AcceleratorConfig::engn();
            fixed_cfg.dataflow = kind;
            let fixed = SimSession::new(&fixed_cfg, &prepared, &model).run("PB").total_cycles();
            if adaptive > fixed {
                return Err(format!(
                    "adaptive {adaptive} > {} {fixed} (n={n} e={e})",
                    kind.name()
                ));
            }
        }
        Ok(())
    });
}

/// Property (2b): the same guarantee on every Table-5 suite pair (the
/// report harness's `adaptive` table is the full-scale view of this).
#[test]
fn adaptive_never_loses_on_any_table5_pair() {
    // Scaled hard so all 15 pairs stay test-fast; the argmin guarantee
    // is scale-free.
    let eval = Eval::new(ScalePolicy::Factor(64), 7);
    for (kind, spec) in eval.suite() {
        let mut cfg = AcceleratorConfig::engn();
        cfg.dataflow = DataflowKind::Adaptive;
        let adaptive = eval.engn_with(cfg, kind, &spec).total_cycles();
        for &df in DataflowKind::fixed() {
            let mut fixed_cfg = AcceleratorConfig::engn();
            fixed_cfg.dataflow = df;
            let fixed = eval.engn_with(fixed_cfg, kind, &spec).total_cycles();
            assert!(
                adaptive <= fixed,
                "{} on {}: adaptive {adaptive} > {} {fixed}",
                kind.name(),
                spec.code,
                df.name()
            );
        }
    }
}

/// Property (2c): estimate pruning is invisible in the outcome — on
/// every Table-5 suite pair, the adaptive planner's per-layer pick
/// equals the argmin of a *full* charge pass over all fixed kinds
/// (computed here from fixed-dataflow sessions, whose per-layer costs
/// are exactly what the planner's charge pass measures, with the same
/// canonical-order tie-break). This pins the satellite contract: the
/// shortlist only skips work, never changes the decision.
#[test]
fn pruned_adaptive_picks_match_full_argmin_on_suite() {
    let eval = Eval::new(ScalePolicy::Factor(64), 7);
    for (kind, spec) in eval.suite() {
        let prepared = eval.prepared(&spec);
        let model = GnnModel::for_dataset(kind, &spec);
        let mut cfg = AcceleratorConfig::engn();
        cfg.dataflow = DataflowKind::Adaptive;
        let plans = SimSession::new(&cfg, &prepared, &model).plan();
        // Reference: per-layer costs of every fixed kind, full pass.
        let fixed_layers: Vec<Vec<f64>> = DataflowKind::fixed()
            .iter()
            .map(|&df| {
                let mut fixed_cfg = AcceleratorConfig::engn();
                fixed_cfg.dataflow = df;
                SimSession::new(&fixed_cfg, &prepared, &model)
                    .run(spec.code)
                    .layers
                    .iter()
                    .map(|l| l.total_cycles)
                    .collect()
            })
            .collect();
        for (l, plan) in plans.iter().enumerate() {
            let mut want = DataflowKind::fixed()[0];
            let mut best = fixed_layers[0][l];
            for (i, &df) in DataflowKind::fixed().iter().enumerate().skip(1) {
                if fixed_layers[i][l] < best {
                    want = df;
                    best = fixed_layers[i][l];
                }
            }
            assert_eq!(
                plan.dataflow,
                want,
                "{} on {} layer {l}: pruned pick {} != full argmin {}",
                kind.name(),
                spec.code,
                plan.dataflow.name(),
                want.name()
            );
        }
    }
}

/// Property (3a): kind names parse back to themselves, the CLI aliases
/// resolve, and the canonical slices agree with the trait objects.
#[test]
fn parse_name_round_trips_and_canonical_slices() {
    for &df in DataflowKind::all() {
        assert_eq!(DataflowKind::parse(df.name()), Some(df), "{}", df.name());
    }
    for (alias, want) in [
        ("versagnn", DataflowKind::SpmmSystolic),
        ("spmm-systolic", DataflowKind::SpmmSystolic),
        ("neurachip", DataflowKind::HashDecoupled),
        ("hash-decoupled", DataflowKind::HashDecoupled),
        ("auto", DataflowKind::Adaptive),
    ] {
        assert_eq!(DataflowKind::parse(alias), Some(want), "{alias}");
    }
    assert_eq!(DataflowKind::fixed().len() + 1, DataflowKind::all().len());
    assert!(!DataflowKind::fixed().contains(&DataflowKind::Adaptive));
    for &df in DataflowKind::fixed() {
        // Every fixed kind resolves to an executable dataflow.
        let _ = dataflow::for_kind_static(df);
    }
}

/// Property (3b): the sampling-extrapolation contract of the two new
/// dataflows — both declare edge-driven cycles, and rescaling a sampled
/// prefix by the sampling factor approximates the full tile on
/// edge-dominated tiles (the premise Phase-fidelity sampling relies
/// on).
#[test]
fn sparse_dataflow_sampling_extrapolation_contract() {
    let cfg = AcceleratorConfig::engn();
    // Edge-dominated tile: the stream term binds both in the full tile
    // and in the quarter sample (distinct counts describe the full tile
    // either way, mirroring how the engine builds sampled TileViews).
    let edges: Vec<Edge> = (0..204_800u32).map(|i| Edge::new(i % 400, i % 2000)).collect();
    let view = TileView {
        edges: &edges,
        grid_row: 0,
        grid_col: 0,
        src_start: 0,
        dst_start: 0,
        span: 4096,
        distinct_src: 400,
        distinct_dst: 2000,
    };
    let mut sampled_view = view;
    sampled_view.edges = &edges[..edges.len() / 4];
    for &kind in &[DataflowKind::SpmmSystolic, DataflowKind::HashDecoupled] {
        let df = dataflow::for_kind_static(kind);
        assert!(df.cycles_scale_with_edges(), "{}", df.name());
        let full = df.aggregate_tile(&cfg, &view);
        let sampled = df.aggregate_tile(&cfg, &sampled_view);
        let extrapolated = sampled.cycles * 4;
        assert!(
            extrapolated >= full.cycles / 2 && extrapolated <= full.cycles * 2,
            "{}: extrapolated {} vs full {}",
            df.name(),
            extrapolated,
            full.cycles
        );
    }
}
