//! Scale-out invariants: property tests over seeded R-MAT graphs pin
//! (1) every edge lands in exactly one chip's subgraph — cross-chip
//! edges additionally in exactly one cut list, (2) a K = 1
//! `MultiChipSession` is bit-identical to a plain `SimSession`, (3) the
//! degree-aware greedy balancer beats range partitioning on every
//! skewed (social) Table-5 graph, (4) `OverlapMode::None` is
//! bit-identical to the pre-overlap model while double-buffering never
//! loses to bulk-sync, and (5) the overlap/partitioner acceptance
//! numbers (≥ 30% comm-stall recovery on Reddit ×8; LDG below the
//! degree balancer's cut ratio on every social graph). CI runs this
//! file at both test-harness widths (see .github/workflows/ci.yml).

use engn::config::AcceleratorConfig;
use engn::graph::datasets::{self, ScalePolicy};
use engn::graph::rmat::{self, RmatParams};
use engn::graph::{Edge, Graph};
use engn::model::{GnnKind, GnnModel};
use engn::partition::{PartitionedGraph, PartitionerKind};
use engn::sim::{ChipLink, MultiChipSession, OverlapMode, PreparedGraph, SimSession};
use engn::util::prop::prop_check;
use std::sync::Arc;

/// Check the coverage invariant for one partition: every global edge
/// appears in exactly one chip's subgraph, cut edges in exactly one cut
/// list, and local ids decode back to the original edge multiset.
fn check_partition(g: &Arc<Graph>, p: &PartitionedGraph) -> Result<(), String> {
    if p.assignment.len() != g.num_vertices {
        return Err("assignment does not cover every vertex".into());
    }
    if p.assignment.iter().any(|&c| (c as usize) >= p.k) {
        return Err("assignment names a chip >= k".into());
    }
    let owned_total: usize = p.chips.iter().map(|c| c.num_owned()).sum();
    if owned_total != g.num_vertices {
        return Err(format!("owned {} != |V| {}", owned_total, g.num_vertices));
    }
    // Edge coverage: internal + cut == E, and each chip's subgraph holds
    // exactly its internal + cut-in edges.
    let internal: usize = p.chips.iter().map(|c| c.internal_edges).sum();
    let cut: usize = (0..p.k).map(|c| p.cut_list(c).len()).sum();
    if internal + cut != g.num_edges() {
        return Err(format!(
            "internal {internal} + cut {cut} != |E| {}",
            g.num_edges()
        ));
    }
    let mut recovered: Vec<Edge> = Vec::with_capacity(g.num_edges());
    for (c, chip) in p.chips.iter().enumerate() {
        let sub = chip.prepared.graph();
        if sub.num_edges() != chip.internal_edges + p.cut_list(c).len() {
            return Err(format!(
                "chip {c} subgraph holds {} edges, want {} internal + {} cut",
                sub.num_edges(),
                chip.internal_edges,
                p.cut_list(c).len()
            ));
        }
        for e in &sub.edges {
            // Destinations are always owned; sources owned or halo.
            if (e.dst as usize) >= chip.num_owned() {
                return Err(format!("chip {c}: destination {} is not owned", e.dst));
            }
            recovered.push(Edge::new(chip.global_of(e.src), chip.global_of(e.dst)));
        }
        // Cut edges cross chips and their destinations are owned here.
        for e in p.cut_list(c) {
            if p.assignment[e.dst as usize] as usize != c {
                return Err(format!("cut edge {e:?} listed on the wrong chip {c}"));
            }
            if p.assignment[e.src as usize] as usize == c {
                return Err(format!("internal edge {e:?} in chip {c}'s cut list"));
            }
        }
        // Halo = distinct cut sources, ascending.
        let mut halo: Vec<u32> = p.cut_list(c).iter().map(|e| e.src).collect();
        halo.sort_unstable();
        halo.dedup();
        if halo != chip.halo {
            return Err(format!("chip {c} halo set mismatch"));
        }
    }
    // The union of all subgraphs is the original edge multiset.
    let key = |e: &Edge| (e.src, e.dst);
    let mut want = g.edges.clone();
    want.sort_unstable_by_key(key);
    recovered.sort_unstable_by_key(key);
    if recovered != want {
        return Err("relabeled subgraphs do not recover the input edges".into());
    }
    Ok(())
}

/// Property (1): partition coverage over random graphs, chip counts and
/// all three strategies.
#[test]
fn prop_every_edge_in_exactly_one_subgraph_or_cut_list() {
    prop_check(20, 0x7117_0003, |rng| {
        let n = rng.gen_usize(8, 500);
        let e = rng.gen_usize(1, 5 * n);
        let k = rng.gen_usize(1, 9);
        let g = Arc::new(rmat::generate(n, e, RmatParams::default(), rng.next_u64()));
        for &kind in PartitionerKind::all() {
            let p = PartitionedGraph::build(g.clone(), kind, k);
            check_partition(&g, &p).map_err(|m| format!("{} k={k}: {m}", kind.name()))?;
        }
        Ok(())
    });
}

/// The counting relabel (seen-bitmask halo gather + epoch-stamped
/// dense local ids) is property-pinned bit-identical to the original
/// sort-dedup-and-binary-search oracle, across every partitioner ×
/// chip count — including a relational (R-GCN) dataset, so relation
/// ids ride the same buckets in both implementations.
#[test]
fn counting_relabel_is_bit_identical_to_reference() {
    let mut graphs: Vec<(&str, Arc<Graph>)> = vec![
        (
            "rmat",
            Arc::new(rmat::generate(1_200, 9_000, RmatParams::default(), 0x51D)),
        ),
    ];
    let af = datasets::by_code("AF").unwrap();
    graphs.push(("AF", Arc::new(af.instantiate(ScalePolicy::Capped, 3))));
    for (label, g) in &graphs {
        for &kind in PartitionerKind::all() {
            for k in [1usize, 2, 4, 7] {
                let fast = PartitionedGraph::build(g.clone(), kind, k);
                let slow = PartitionedGraph::build_reference(g.clone(), kind, k);
                let tag = format!("{label} {} k={k}", kind.name());
                assert_eq!(fast.assignment, slow.assignment, "{tag}");
                assert_eq!(fast.total_edges, slow.total_edges, "{tag}");
                for (a, b) in fast.chips.iter().zip(&slow.chips) {
                    assert_eq!(a.owned, b.owned, "{tag} chip {}", a.chip);
                    assert_eq!(a.halo, b.halo, "{tag} chip {}", a.chip);
                    assert_eq!(a.internal_edges, b.internal_edges, "{tag} chip {}", a.chip);
                    let (ga, gb) = (a.prepared.graph(), b.prepared.graph());
                    assert_eq!(ga.edges, gb.edges, "{tag} chip {}", a.chip);
                    assert_eq!(ga.relations, gb.relations, "{tag} chip {}", a.chip);
                    assert_eq!(ga.num_relations, gb.num_relations, "{tag} chip {}", a.chip);
                }
                for c in 0..k {
                    assert_eq!(fast.cut_list(c), slow.cut_list(c), "{tag} chip {c}");
                }
            }
        }
    }
}

fn assert_reports_identical(a: &engn::sim::SimReport, b: &engn::sim::SimReport) {
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_ops(), b.total_ops());
    assert_eq!(a.chip_energy_j, b.chip_energy_j);
    assert_eq!(a.hbm_energy_j, b.hbm_energy_j);
    assert_eq!(a.power_w, b.power_w);
    assert_eq!(a.davc().accesses, b.davc().accesses);
    assert_eq!(a.davc().hits, b.davc().hits);
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.q, lb.q);
        assert_eq!(la.total_cycles, lb.total_cycles);
        assert_eq!(la.traffic.hbm_read_bytes, lb.traffic.hbm_read_bytes);
        assert_eq!(la.traffic.hbm_write_bytes, lb.traffic.hbm_write_bytes);
    }
}

/// Property (2): a K = 1 multi-chip session IS the single-chip session —
/// same graph, zero communication, bit-identical report — for every
/// partitioner and both link topologies.
#[test]
fn k1_multichip_session_bit_identical_to_sim_session() {
    let spec = datasets::by_code("PB").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(8), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    let prepared = PreparedGraph::from_arc(g.clone());
    let single = SimSession::new(&cfg, &prepared, &model).run("PB");
    for &kind in PartitionerKind::all() {
        let parts = PartitionedGraph::build(g.clone(), kind, 1);
        for link in [ChipLink::ring(), ChipLink::all_to_all()] {
            let multi = MultiChipSession::new(&cfg, &parts, &model)
                .with_link(link)
                .run("PB");
            assert_eq!(multi.chips, 1, "{}", kind.name());
            assert_eq!(multi.comm_cycles(), 0.0);
            assert_eq!(multi.comm_bytes, 0.0);
            assert_eq!(multi.total_cycles(), single.total_cycles(), "{}", kind.name());
            assert_eq!(multi.energy_j(), single.energy_j());
            assert_reports_identical(&multi.per_chip[0], &single);
        }
    }
}

/// Property (3): on every skewed Table-5 social graph, the degree-aware
/// greedy balancer achieves a strictly lower max-chip edge load (and a
/// better max/min ratio) than range partitioning.
#[test]
fn degree_balancer_beats_range_on_every_social_graph() {
    for spec in datasets::all().iter().filter(|d| {
        matches!(d.group, engn::graph::datasets::DatasetGroup::Social)
    }) {
        // Scaled hard so the three social graphs stay test-fast; the
        // R-MAT skew (and therefore the range imbalance) is scale-free.
        let g = Arc::new(spec.instantiate(ScalePolicy::Factor(512), 7));
        for k in [4usize, 8] {
            let range = PartitionedGraph::build(g.clone(), PartitionerKind::Range, k);
            let degree = PartitionedGraph::build(g.clone(), PartitionerKind::Degree, k);
            let range_max = *range.edge_loads().iter().max().unwrap();
            let degree_max = *degree.edge_loads().iter().max().unwrap();
            assert!(
                degree_max < range_max,
                "{} k={k}: degree max {degree_max} !< range max {range_max}",
                spec.code
            );
            assert!(
                degree.max_min_load_ratio() <= range.max_min_load_ratio(),
                "{} k={k}: ratio {} > {}",
                spec.code,
                degree.max_min_load_ratio(),
                range.max_min_load_ratio()
            );
        }
    }
}

/// Scale-out pays off where it should: 4 chips beat 1 on a social graph
/// and the communication stall is visible but not dominant under the
/// default SerDes-class ring.
#[test]
fn four_chip_scaleout_beats_single_chip_on_reddit() {
    let spec = datasets::by_code("RD").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(256), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::GsPool, &spec);
    let cfg = AcceleratorConfig::engn();
    let prepared = PreparedGraph::from_arc(g.clone());
    let single = SimSession::new(&cfg, &prepared, &model).run("RD");
    let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 4);
    let multi = MultiChipSession::new(&cfg, &parts, &model).run("RD");
    assert!(multi.cut_edges > 0 && multi.comm_cycles() > 0.0);
    assert!(
        multi.total_cycles() < single.total_cycles(),
        "4-chip {} !< 1-chip {}",
        multi.total_cycles(),
        single.total_cycles()
    );
    assert!(multi.comm_fraction() < 0.5, "comm dominates: {}", multi.comm_fraction());
}

/// Property (4a): `OverlapMode::None` — explicitly set, at any pipeline
/// depth — is bit-identical to the default (pre-overlap) session across
/// every partitioner, both link topologies and several chip counts: the
/// overlap plumbing must be invisible until it is switched on.
#[test]
fn overlap_none_is_bit_identical_across_partitioners_topologies_and_k() {
    let spec = datasets::by_code("PB").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(8), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    for &kind in PartitionerKind::all() {
        for k in [1usize, 2, 4] {
            let parts = PartitionedGraph::build(g.clone(), kind, k);
            for link in [ChipLink::ring(), ChipLink::all_to_all()] {
                let tag = format!("{} k={k} {}", kind.name(), link.topology.name());
                let base = MultiChipSession::new(&cfg, &parts, &model)
                    .with_link(link)
                    .run("PB");
                let none = MultiChipSession::new(&cfg, &parts, &model)
                    .with_link(link)
                    .with_overlap(OverlapMode::None)
                    .with_pipeline_depth(3)
                    .run("PB");
                assert_eq!(none.total_cycles(), base.total_cycles(), "{tag}");
                assert_eq!(none.layer_cycles, base.layer_cycles, "{tag}");
                assert_eq!(none.layer_comm_cycles, base.layer_comm_cycles, "{tag}");
                assert_eq!(none.comm_bytes, base.comm_bytes, "{tag}");
                assert_eq!(none.energy_j(), base.energy_j(), "{tag}");
                assert_eq!(none.comm_hidden_cycles(), 0.0, "{tag}");
                assert!(
                    none.layer_comm_hidden_cycles.iter().all(|&h| h == 0.0),
                    "{tag}: bulk-sync hid comm"
                );
                for (ra, rb) in none.per_chip.iter().zip(&base.per_chip) {
                    assert_reports_identical(ra, rb);
                }
            }
        }
    }
}

/// Property (4b): double-buffering can only help — the overlapped total
/// never exceeds bulk-sync for any partitioner or chip count, the two
/// are exactly equal at K = 1 (no exchange to hide), and per-chip
/// compute reports are untouched by the overlap mode.
#[test]
fn double_buffer_total_never_exceeds_bulk_sync_and_matches_at_k1() {
    let spec = datasets::by_code("PB").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(8), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    for &kind in PartitionerKind::all() {
        for k in [1usize, 2, 4, 8] {
            let parts = PartitionedGraph::build(g.clone(), kind, k);
            let bulk = MultiChipSession::new(&cfg, &parts, &model).run("PB");
            let db = MultiChipSession::new(&cfg, &parts, &model)
                .with_overlap(OverlapMode::DoubleBuffer)
                .run("PB");
            let tag = format!("{} k={k}", kind.name());
            assert!(
                db.total_cycles() <= bulk.total_cycles(),
                "{tag}: overlapped {} > bulk {}",
                db.total_cycles(),
                bulk.total_cycles()
            );
            for (l, (&c, &f)) in db.layer_comm_cycles.iter().zip(&bulk.layer_comm_cycles).enumerate()
            {
                assert!(c <= f, "{tag} layer {l}: charged {c} > full {f}");
            }
            for (ra, rb) in db.per_chip.iter().zip(&bulk.per_chip) {
                assert_reports_identical(ra, rb);
            }
            if k == 1 {
                assert_eq!(db.total_cycles(), bulk.total_cycles(), "{tag}");
                assert_eq!(db.comm_hidden_cycles(), 0.0, "{tag}");
            }
        }
    }
}

/// Acceptance pin: on the Reddit pair (GS-Pool, the paper's Table-5
/// pairing) at K = 8, double-buffered overlap hides at least 30% of the
/// bulk-synchronous communication stall.
#[test]
fn double_buffer_recovers_comm_stall_on_reddit_k8() {
    let spec = datasets::by_code("RD").unwrap();
    let g = Arc::new(spec.instantiate(ScalePolicy::Factor(256), 0xE16A));
    let model = GnnModel::for_dataset(GnnKind::GsPool, &spec);
    let cfg = AcceleratorConfig::engn();
    let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 8);
    let r = MultiChipSession::new(&cfg, &parts, &model)
        .with_overlap(OverlapMode::DoubleBuffer)
        .run("RD");
    assert!(r.comm_hidden_cycles() > 0.0);
    assert!(
        r.comm_recovered_fraction() >= 0.30,
        "recovered only {:.1}% of the comm stall",
        100.0 * r.comm_recovered_fraction()
    );
}

/// Acceptance pin: the streaming LDG partitioner's neighbor-affinity
/// placement cuts strictly fewer edges than the degree-aware greedy
/// balancer on every skewed Table-5 social graph at K ∈ {4, 8} — the
/// balancer optimizes load alone, LDG trades a bounded load slack
/// (hard capacity ⌈n/k⌉) for locality.
#[test]
fn ldg_cuts_fewer_edges_than_degree_on_every_social_graph() {
    for spec in datasets::all().iter().filter(|d| {
        matches!(d.group, engn::graph::datasets::DatasetGroup::Social)
    }) {
        let g = Arc::new(spec.instantiate(ScalePolicy::Factor(512), 7));
        for k in [4usize, 8] {
            let degree = PartitionedGraph::build(g.clone(), PartitionerKind::Degree, k);
            let ldg = PartitionedGraph::build(g.clone(), PartitionerKind::Ldg, k);
            assert!(
                ldg.cut_ratio() < degree.cut_ratio(),
                "{} k={k}: ldg cut {:.4} !< degree cut {:.4}",
                spec.code,
                ldg.cut_ratio(),
                degree.cut_ratio()
            );
        }
    }
}

/// Determinism: the chip fan-out collects per-chip reports by index, so
/// a multi-chip run is bit-identical across repeated (parallel) runs.
#[test]
fn repeated_multichip_runs_are_bit_identical() {
    let g = Arc::new(rmat::generate(3_000, 24_000, RmatParams::default(), 21));
    let spec = datasets::by_code("PB").unwrap();
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    let parts = PartitionedGraph::build(g, PartitionerKind::Hash, 3);
    let session = MultiChipSession::new(&cfg, &parts, &model);
    let a = session.run("PB");
    let b = session.run("PB");
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.comm_bytes, b.comm_bytes);
    assert_eq!(a.energy_j(), b.energy_j());
    for (ra, rb) in a.per_chip.iter().zip(&b.per_chip) {
        assert_reports_identical(ra, rb);
    }
}
