//! Integration: the multi-plane serving coordinator under concurrent
//! multi-key load — genuine worker parallelism, mixed tensor/sim/cost
//! job streams through one service, deadline- and cancel-shedding at
//! batch formation, shutdown-drain semantics, and bounded-intake
//! backpressure observable as typed `Busy` rejections. Tensor planes
//! run against mock executors, so these tests need no compiled
//! artifacts; the sim/cost planes are the real analytic backends.

use engn::coordinator::{
    Backends, BatchConfig, CostJob, Executor, InferenceService, JobError, JobOutput,
    JobPayload, ServiceConfig, SimJob, SubmitError,
};
use engn::model::GnnKind;
use engn::runtime::HostTensor;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn ok_tensor(n: usize) -> Result<HostTensor, String> {
    Ok(HostTensor::new(vec![1], vec![n as f32]))
}

/// Executor whose batches rendezvous: each `execute_batch` holds until
/// `target` executions overlap (or a 2 s timeout), so a passing run
/// proves ≥`target` worker threads were genuinely concurrent.
struct Rendezvous {
    inflight: Arc<AtomicUsize>,
    max_inflight: Arc<AtomicUsize>,
    target: usize,
}

impl Executor for Rendezvous {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        _artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        let now = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_inflight.fetch_max(now, Ordering::SeqCst);
        let t0 = Instant::now();
        while self.inflight.load(Ordering::SeqCst) < self.target
            && self.max_inflight.load(Ordering::SeqCst) < self.target
            && t0.elapsed() < Duration::from_secs(2)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

/// Two workers must serve two distinct artifacts at the same time: the
/// rendezvous executor only releases once two executions overlap.
#[test]
fn two_workers_serve_distinct_artifacts_concurrently() {
    let inflight = Arc::new(AtomicUsize::new(0));
    let max_inflight = Arc::new(AtomicUsize::new(0));
    let (infl, maxi) = (inflight.clone(), max_inflight.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Backends::tensor(Box::new(Rendezvous {
                inflight: infl.clone(),
                max_inflight: maxi.clone(),
                target: 2,
            })))
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_capacity: 64,
            ..Default::default()
        },
    );
    let mut tickets = Vec::new();
    for artifact in ["gcn", "gcn", "grn", "grn"] {
        tickets.push(svc.submit_tensor(artifact, vec![]).expect("accepted"));
    }
    for ticket in tickets {
        let resp = ticket.wait();
        assert!(resp.result.is_ok(), "{:?}", resp.result);
    }
    assert!(
        max_inflight.load(Ordering::SeqCst) >= 2,
        "never observed two executions in flight: workers are not concurrent"
    );
    let m = svc.metrics();
    assert_eq!(m.total_requests, 4);
    assert_eq!(m.workers, 2);
    assert!(m.per_key.contains_key("tensor:gcn"));
    assert!(m.per_key.contains_key("tensor:grn"));
    svc.shutdown();
}

/// Executor gated on a flag: enters, signals, and blocks until released.
/// Lets the backpressure/shedding tests control execution timing
/// deterministically.
struct Gate {
    entered: Arc<AtomicUsize>,
    release: Arc<AtomicBool>,
}

impl Executor for Gate {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        _artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

fn gate_service(
    entered: &Arc<AtomicUsize>,
    release: &Arc<AtomicBool>,
    queue_capacity: usize,
) -> InferenceService {
    let (ent, rel) = (entered.clone(), release.clone());
    InferenceService::start(
        move || {
            Ok(Backends::tensor(Box::new(Gate {
                entered: ent.clone(),
                release: rel.clone(),
            })))
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            workers: 1,
            queue_capacity,
            ..Default::default()
        },
    )
}

/// With the single worker parked inside the executor, the bounded queue
/// fills to capacity and the next submission is shed with a typed
/// `Busy` — not queued, not an opaque string.
#[test]
fn bounded_intake_sheds_with_typed_busy() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let svc = gate_service(&entered, &release, 3);
    // First request is pulled by the worker, which then blocks on the gate.
    let first = svc.submit_tensor("gcn", vec![]).expect("accepted");
    let t0 = Instant::now();
    while entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Fill the intake queue to capacity behind the parked worker…
    let queued: Vec<_> = (0..3)
        .map(|_| svc.submit_tensor("gcn", vec![]).expect("fits capacity"))
        .collect();
    // …and the next submission must be shed, typed.
    let err = svc.submit_tensor("gcn", vec![]).unwrap_err();
    assert_eq!(
        err,
        SubmitError::Busy {
            queue_depth: 3,
            capacity: 3
        }
    );
    assert_eq!(svc.metrics().rejected, 1);
    // Release the gate: every accepted request still completes.
    release.store(true, Ordering::SeqCst);
    assert!(first.wait().result.is_ok());
    for ticket in queued {
        assert!(ticket.wait().result.is_ok());
    }
    svc.shutdown();
}

/// Acceptance: a deadline-expired job is shed AT BATCH FORMATION —
/// answered `Expired`, never handed to the executor — and the `expired`
/// metrics counter records it. Jobs around it execute normally.
#[test]
fn deadline_expired_job_is_shed_at_batch_formation() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let svc = gate_service(&entered, &release, 8);
    // Park the single worker inside the first job's execution…
    let first = svc.submit_tensor("gcn", vec![]).expect("accepted");
    let t0 = Instant::now();
    while entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // …queue a deadlined job and a live job behind it…
    let doomed = svc
        .submit_with_deadline(
            JobPayload::Tensor {
                artifact: "gcn".into(),
                inputs: vec![],
            },
            Duration::from_millis(5),
        )
        .expect("accepted");
    let live = svc.submit_tensor("gcn", vec![]).expect("accepted");
    // …let the deadline pass while the worker is still parked…
    std::thread::sleep(Duration::from_millis(25));
    assert!(doomed.try_poll().is_none(), "not answered before formation");
    // …then release: formation sheds the expired job and executes only
    // the live one.
    release.store(true, Ordering::SeqCst);
    assert!(first.wait().result.is_ok());
    let doomed_resp = doomed.wait();
    assert!(
        matches!(doomed_resp.result, Err(JobError::Expired)),
        "{:?}",
        doomed_resp.result
    );
    assert_eq!(doomed_resp.batch_size, 0, "expired job served by no batch");
    assert!(live.wait().result.is_ok());
    svc.shutdown();
    assert_eq!(
        entered.load(Ordering::SeqCst),
        2,
        "executor must see exactly the two live jobs, never the expired one"
    );
}

/// `Ticket::cancel` before execution sheds the job at batch formation,
/// answers `Cancelled`, and the executor never sees it.
#[test]
fn cancelled_job_is_shed_at_batch_formation() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let svc = gate_service(&entered, &release, 8);
    let first = svc.submit_tensor("gcn", vec![]).expect("accepted");
    let t0 = Instant::now();
    while entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    let victim = svc.submit_tensor("gcn", vec![]).expect("accepted");
    assert!(victim.cancel(), "cancel before execution must win");
    release.store(true, Ordering::SeqCst);
    assert!(first.wait().result.is_ok());
    let resp = victim.wait();
    assert!(matches!(resp.result, Err(JobError::Cancelled)), "{:?}", resp.result);
    let m = svc.metrics();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.expired, 0);
    svc.shutdown();
    assert_eq!(entered.load(Ordering::SeqCst), 1, "victim must never execute");
}

/// Mock with a fixed per-batch delay (default `execute_batch` loop).
struct Slow(Duration);

impl Executor for Slow {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        std::thread::sleep(self.0);
        ok_tensor(inputs.len())
    }
}

/// `shutdown` must drain: every job accepted before the stop flag is
/// answered (with a real result, not an error), and only then do the
/// workers exit.
#[test]
fn shutdown_drains_accepted_requests() {
    let svc = InferenceService::start(
        || Ok(Backends::tensor(Box::new(Slow(Duration::from_millis(3))))),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_capacity: 64,
            ..Default::default()
        },
    );
    let tickets: Vec<_> = (0..12)
        .map(|i| {
            let artifact = if i % 3 == 0 { "grn" } else { "gcn" };
            svc.submit_tensor(artifact, vec![]).expect("accepted")
        })
        .collect();
    // Blocks until both workers have drained the queues and joined.
    svc.shutdown();
    for ticket in tickets {
        let resp = ticket.wait();
        assert!(resp.result.is_ok(), "{:?}", resp.result);
    }
}

/// Acceptance: tensor, simulation and cost-model jobs are served
/// through ONE `InferenceService` end to end, concurrently, each
/// answered by its own execution plane with the right output variant
/// and its own batching key in the metrics.
#[test]
fn mixed_tensor_and_sim_jobs_served_concurrently() {
    let svc = Arc::new(InferenceService::start(
        || Ok(Backends::full(Box::new(Slow(Duration::from_micros(200))))),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 3,
            queue_capacity: 1024,
            ..Default::default()
        },
    ));
    let mut clients = Vec::new();
    for c in 0..3usize {
        let svc = svc.clone();
        clients.push(std::thread::spawn(move || {
            let mut tickets = Vec::new();
            for i in 0..9usize {
                let payload = match (c + i) % 3 {
                    0 => JobPayload::Tensor {
                        artifact: "gcn".to_string(),
                        inputs: vec![],
                    },
                    1 => JobPayload::Sim(SimJob::new(GnnKind::Gcn, "CA")),
                    _ => JobPayload::Cost(CostJob::new(
                        engn::baselines::PlatformId::Hygcn,
                        GnnKind::Gcn,
                        "CA",
                    )),
                };
                let kind = payload.kind();
                tickets.push((kind, svc.submit(payload).expect("accepted")));
            }
            for (kind, ticket) in tickets {
                let resp = ticket.wait();
                match (kind, resp.result.expect("job ok")) {
                    (engn::coordinator::JobKind::Tensor, JobOutput::Tensor(_)) => {}
                    (engn::coordinator::JobKind::Sim, JobOutput::Sim(s)) => {
                        assert_eq!(s.dataset, "CA");
                        assert!(s.seconds > 0.0 && s.energy_j > 0.0);
                    }
                    (engn::coordinator::JobKind::Cost, JobOutput::Cost(cst)) => {
                        assert_eq!(cst.platform, "HyGCN");
                        assert!(cst.seconds > 0.0);
                    }
                    (k, out) => panic!("plane mismatch: {k:?} answered with {out:?}"),
                }
            }
        }));
    }
    for cl in clients {
        cl.join().expect("client thread");
    }
    let m = svc.metrics();
    assert_eq!(m.total_requests, 27);
    assert_eq!(m.rejected, 0);
    assert_eq!(m.expired, 0);
    assert!(m.per_key.contains_key("tensor:gcn"), "{:?}", m.per_key.keys());
    assert!(m.per_key.contains_key("sim:EnGN:CA"), "{:?}", m.per_key.keys());
    assert!(m.per_key.contains_key("cost:HyGCN"), "{:?}", m.per_key.keys());
    for (key, s) in &m.per_key {
        assert_eq!(s.errors, 0, "{key} had errors");
        assert!(s.count > 0, "{key} served nothing");
        assert!(s.mean_batch >= 1.0);
    }
    Arc::try_unwrap(svc)
        .unwrap_or_else(|_| panic!("service still shared"))
        .shutdown();
}

/// Soak: several client threads hammer three artifacts across three
/// workers; every job is answered exactly once and the merged metrics
/// account for all of them.
#[test]
fn concurrent_clients_multi_artifact_soak() {
    let svc = Arc::new(InferenceService::start(
        || Ok(Backends::tensor(Box::new(Slow(Duration::from_micros(200))))),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 3,
            queue_capacity: 1024,
            ..Default::default()
        },
    ));
    let ids = Arc::new(Mutex::new(std::collections::HashSet::new()));
    let mut clients = Vec::new();
    for c in 0..4 {
        let svc = svc.clone();
        let ids = ids.clone();
        clients.push(std::thread::spawn(move || {
            let artifacts = ["gcn", "grn", "rgcn"];
            let mut tickets = Vec::new();
            for i in 0..25 {
                let artifact = artifacts[(c + i) % 3];
                let ticket = svc.submit_tensor(artifact, vec![]).expect("accepted");
                assert!(ids.lock().unwrap().insert(ticket.id()), "duplicate job id");
                tickets.push(ticket);
            }
            for ticket in tickets {
                assert!(ticket.wait().result.is_ok());
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }
    let m = svc.metrics();
    assert_eq!(m.total_requests, 100);
    assert_eq!(m.rejected, 0);
    let per_key_total: u64 = m.per_key.values().map(|s| s.count).sum();
    assert_eq!(per_key_total, 100);
    for s in m.per_key.values() {
        assert_eq!(s.errors, 0);
        assert!(s.mean_batch >= 1.0);
        assert!(s.throughput_rps > 0.0);
    }
    Arc::try_unwrap(svc)
        .unwrap_or_else(|_| panic!("service still shared"))
        .shutdown();
}
