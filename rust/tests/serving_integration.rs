//! Integration: the multi-worker serving coordinator under concurrent
//! multi-artifact load — genuine worker parallelism, shutdown-drain
//! semantics, and bounded-intake backpressure observable as typed
//! `Busy` rejections. Everything runs against mock executors, so these
//! tests need no compiled artifacts.

use engn::coordinator::{
    BatchConfig, Executor, InferenceService, ServiceConfig, SubmitError,
};
use engn::runtime::HostTensor;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn ok_tensor(n: usize) -> Result<HostTensor, String> {
    Ok(HostTensor::new(vec![1], vec![n as f32]))
}

/// Executor whose batches rendezvous: each `execute_batch` holds until
/// `target` executions overlap (or a 2 s timeout), so a passing run
/// proves ≥`target` worker threads were genuinely concurrent.
struct Rendezvous {
    inflight: Arc<AtomicUsize>,
    max_inflight: Arc<AtomicUsize>,
    target: usize,
}

impl Executor for Rendezvous {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        _artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        let now = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.max_inflight.fetch_max(now, Ordering::SeqCst);
        let t0 = Instant::now();
        while self.inflight.load(Ordering::SeqCst) < self.target
            && self.max_inflight.load(Ordering::SeqCst) < self.target
            && t0.elapsed() < Duration::from_secs(2)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

/// Two workers must serve two distinct artifacts at the same time: the
/// rendezvous executor only releases once two executions overlap.
#[test]
fn two_workers_serve_distinct_artifacts_concurrently() {
    let inflight = Arc::new(AtomicUsize::new(0));
    let max_inflight = Arc::new(AtomicUsize::new(0));
    let (infl, maxi) = (inflight.clone(), max_inflight.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Box::new(Rendezvous {
                inflight: infl.clone(),
                max_inflight: maxi.clone(),
                target: 2,
            }) as Box<dyn Executor>)
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_capacity: 64,
        },
    );
    let mut rxs = Vec::new();
    for artifact in ["gcn", "gcn", "grn", "grn"] {
        rxs.push(svc.submit(artifact, vec![]).expect("accepted").1);
    }
    for rx in rxs {
        let resp = rx.recv().expect("answered");
        assert!(resp.result.is_ok(), "{:?}", resp.result);
    }
    assert!(
        max_inflight.load(Ordering::SeqCst) >= 2,
        "never observed two executions in flight: workers are not concurrent"
    );
    let m = svc.metrics();
    assert_eq!(m.total_requests, 4);
    assert_eq!(m.workers, 2);
    assert!(m.per_artifact.contains_key("gcn"));
    assert!(m.per_artifact.contains_key("grn"));
    svc.shutdown();
}

/// Executor gated on a flag: enters, signals, and blocks until released.
/// Lets the backpressure test fill the intake queue deterministically.
struct Gate {
    entered: Arc<AtomicUsize>,
    release: Arc<AtomicBool>,
}

impl Executor for Gate {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        ok_tensor(inputs.len())
    }

    fn execute_batch(
        &self,
        _artifact: &str,
        batches: &[Vec<HostTensor>],
    ) -> Vec<Result<HostTensor, String>> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        batches.iter().map(|b| ok_tensor(b.len())).collect()
    }
}

/// With the single worker parked inside the executor, the bounded queue
/// fills to capacity and the next submission is shed with a typed
/// `Busy` — not queued, not an opaque string.
#[test]
fn bounded_intake_sheds_with_typed_busy() {
    let entered = Arc::new(AtomicUsize::new(0));
    let release = Arc::new(AtomicBool::new(false));
    let (ent, rel) = (entered.clone(), release.clone());
    let svc = InferenceService::start(
        move || {
            Ok(Box::new(Gate {
                entered: ent.clone(),
                release: rel.clone(),
            }) as Box<dyn Executor>)
        },
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            workers: 1,
            queue_capacity: 3,
        },
    );
    // First request is pulled by the worker, which then blocks on the gate.
    let (_, first_rx) = svc.submit("gcn", vec![]).expect("accepted");
    let t0 = Instant::now();
    while entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "worker never started");
        std::thread::sleep(Duration::from_millis(1));
    }
    // Fill the intake queue to capacity behind the parked worker…
    let queued: Vec<_> = (0..3)
        .map(|_| svc.submit("gcn", vec![]).expect("fits capacity").1)
        .collect();
    // …and the next submission must be shed, typed.
    let err = svc.submit("gcn", vec![]).unwrap_err();
    assert_eq!(
        err,
        SubmitError::Busy {
            queue_depth: 3,
            capacity: 3
        }
    );
    assert_eq!(svc.metrics().rejected, 1);
    // Release the gate: every accepted request still completes.
    release.store(true, Ordering::SeqCst);
    assert!(first_rx.recv().expect("answered").result.is_ok());
    for rx in queued {
        assert!(rx.recv().expect("answered").result.is_ok());
    }
    svc.shutdown();
}

/// Mock with a fixed per-batch delay (default `execute_batch` loop).
struct Slow(Duration);

impl Executor for Slow {
    fn execute(&self, _artifact: &str, inputs: &[HostTensor]) -> Result<HostTensor, String> {
        std::thread::sleep(self.0);
        ok_tensor(inputs.len())
    }
}

/// `shutdown` must drain: every request accepted before the stop flag is
/// answered (with a real result, not an error), and only then do the
/// workers exit.
#[test]
fn shutdown_drains_accepted_requests() {
    let svc = InferenceService::start(
        || Ok(Box::new(Slow(Duration::from_millis(3))) as Box<dyn Executor>),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_capacity: 64,
        },
    );
    let rxs: Vec<_> = (0..12)
        .map(|i| {
            let artifact = if i % 3 == 0 { "grn" } else { "gcn" };
            svc.submit(artifact, vec![]).expect("accepted").1
        })
        .collect();
    // Blocks until both workers have drained the queues and joined.
    svc.shutdown();
    for rx in rxs {
        let resp = rx.recv().expect("drained requests are answered");
        assert!(resp.result.is_ok(), "{:?}", resp.result);
    }
}

/// Soak: several client threads hammer three artifacts across three
/// workers; every request is answered exactly once and the merged
/// metrics account for all of them.
#[test]
fn concurrent_clients_multi_artifact_soak() {
    let svc = Arc::new(InferenceService::start(
        || Ok(Box::new(Slow(Duration::from_micros(200))) as Box<dyn Executor>),
        ServiceConfig {
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            workers: 3,
            queue_capacity: 1024,
        },
    ));
    let ids = Arc::new(Mutex::new(std::collections::HashSet::new()));
    let mut clients = Vec::new();
    for c in 0..4 {
        let svc = svc.clone();
        let ids = ids.clone();
        clients.push(std::thread::spawn(move || {
            let artifacts = ["gcn", "grn", "rgcn"];
            let mut rxs = Vec::new();
            for i in 0..25 {
                let artifact = artifacts[(c + i) % 3];
                let (id, rx) = svc.submit(artifact, vec![]).expect("accepted");
                assert!(ids.lock().unwrap().insert(id), "duplicate request id");
                rxs.push(rx);
            }
            for rx in rxs {
                assert!(rx.recv().expect("answered").result.is_ok());
            }
        }));
    }
    for c in clients {
        c.join().expect("client thread");
    }
    let m = svc.metrics();
    assert_eq!(m.total_requests, 100);
    assert_eq!(m.rejected, 0);
    let per_artifact_total: u64 = m.per_artifact.values().map(|s| s.count).sum();
    assert_eq!(per_artifact_total, 100);
    for s in m.per_artifact.values() {
        assert_eq!(s.errors, 0);
        assert!(s.mean_batch >= 1.0);
        assert!(s.throughput_rps > 0.0);
    }
    Arc::try_unwrap(svc)
        .unwrap_or_else(|_| panic!("service still shared"))
        .shutdown();
}
