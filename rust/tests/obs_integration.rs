//! Observability-plane integration: (1) the traced run is invisible in
//! the report — `run()` and `run_traced().0` are bit-identical for both
//! the single-chip and the scale-out session, (2) the rendered Chrome
//! trace is byte-identical at pool width 1 and the default width and
//! across repeats (spans are assembled serially from by-index results),
//! (3) the Chrome JSON parses with the crate's own parser and carries
//! the full layer → stage → tile hierarchy (plus `chipN/…`, `halo`, and
//! `mem` tracks where they apply), and (4) the registry counters the
//! CLI records agree with the report fields they were projected from.
//! CI runs this file at both test-harness widths (see
//! .github/workflows/ci.yml).

use engn::config::AcceleratorConfig;
use engn::graph::datasets::{DatasetGroup, DatasetSpec};
use engn::graph::rmat::{self, RmatParams};
use engn::model::{GnnKind, GnnModel};
use engn::obs;
use engn::partition::{PartitionedGraph, PartitionerKind};
use engn::sim::{MultiChipSession, PreparedGraph, SimReport, SimSession};
use engn::util::{json, pool};
use std::sync::Arc;

/// Seeded synthetic workload shared by every test: big enough that the
/// session's layer fan-out actually goes wide, small enough to stay
/// fast.
fn spec() -> DatasetSpec {
    DatasetSpec {
        code: "OBS",
        name: "obs-integration",
        vertices: 3_000,
        edges: 40_000,
        feature_dim: 128,
        labels: 16,
        num_relations: 1,
        group: DatasetGroup::Synthetic,
    }
}

fn workload() -> (Arc<engn::graph::Graph>, GnnModel) {
    let s = spec();
    let g = Arc::new(rmat::generate(s.vertices, s.edges, RmatParams::default(), 0x0B5E));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &s);
    (g, model)
}

fn assert_reports_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.config_name, b.config_name);
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_ops(), b.total_ops());
    assert_eq!(a.chip_energy_j, b.chip_energy_j);
    assert_eq!(a.hbm_energy_j, b.hbm_energy_j);
    assert_eq!(a.power_w, b.power_w);
    assert_eq!(a.traffic().hbm_read_bytes, b.traffic().hbm_read_bytes);
    assert_eq!(a.traffic().hbm_write_bytes, b.traffic().hbm_write_bytes);
    assert_eq!(a.davc().accesses, b.davc().accesses);
    assert_eq!(a.davc().hits, b.davc().hits);
    assert_eq!(a.spilled_bytes(), b.spilled_bytes());
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.layer_idx, lb.layer_idx);
        assert_eq!(la.q, lb.q);
        assert_eq!(la.feature_extraction.cycles, lb.feature_extraction.cycles);
        assert_eq!(la.aggregate.cycles, lb.aggregate.cycles);
        assert_eq!(la.update.cycles, lb.update.cycles);
        assert_eq!(la.total_cycles, lb.total_cycles);
    }
}

/// Zero-cost pin, single chip: the traced run returns the same report
/// the plain run does, bit for bit.
#[test]
fn traced_sim_report_bit_identical_to_untraced() {
    let (g, model) = workload();
    let cfg = AcceleratorConfig::engn();
    let prepared = PreparedGraph::from_arc(g);
    let session = SimSession::new(&cfg, &prepared, &model);
    let plain = session.run("OBS");
    let (traced, trace) = session.run_traced("OBS");
    assert_reports_identical(&plain, &traced);
    assert!(!trace.is_empty());
}

/// Zero-cost pin, scale-out: `run_traced().0` matches `run()` at K = 4
/// and at the K = 1 degenerate point (where the trace still carries the
/// chip-0 hierarchy but no halo spans).
#[test]
fn traced_scaleout_report_bit_identical_to_untraced() {
    let (g, model) = workload();
    let cfg = AcceleratorConfig::engn();
    for k in [1usize, 4] {
        let parts = PartitionedGraph::build(g.clone(), PartitionerKind::Degree, k);
        let session = MultiChipSession::new(&cfg, &parts, &model);
        let plain = session.run("OBS");
        let (traced, trace) = session.run_traced("OBS");
        assert_eq!(plain.total_cycles(), traced.total_cycles(), "k={k}");
        assert_eq!(plain.comm_bytes, traced.comm_bytes, "k={k}");
        assert_eq!(plain.layer_cycles, traced.layer_cycles, "k={k}");
        assert_eq!(plain.layer_comm_cycles, traced.layer_comm_cycles, "k={k}");
        assert_eq!(plain.halo_vertices, traced.halo_vertices, "k={k}");
        assert_eq!(plain.per_chip.len(), traced.per_chip.len(), "k={k}");
        for (a, b) in plain.per_chip.iter().zip(&traced.per_chip) {
            assert_reports_identical(a, b);
        }
        let has_halo = trace.tracks().iter().any(|t| t == "halo");
        assert_eq!(has_halo, k > 1, "k={k}: halo track presence");
    }
}

/// Determinism: the rendered Chrome JSON is byte-identical across
/// repeats at the harness's default pool width, and byte-identical to a
/// run forced to width 1 (a spawned thread with a huge width share
/// floors every parallel map at one worker without touching the global
/// pool override).
#[test]
fn trace_bytes_identical_at_width_one_and_wide() {
    let (g, model) = workload();
    let cfg = AcceleratorConfig::engn();
    let prepared = PreparedGraph::from_arc(g.clone());
    let wide_a = SimSession::new(&cfg, &prepared, &model)
        .run_traced("OBS")
        .1
        .to_chrome_json()
        .to_string_pretty();
    let wide_b = SimSession::new(&cfg, &prepared, &model)
        .run_traced("OBS")
        .1
        .to_chrome_json()
        .to_string_pretty();
    assert_eq!(wide_a, wide_b, "repeat runs rendered different traces");

    let narrow = {
        let g = g.clone();
        let model = model.clone();
        std::thread::spawn(move || {
            pool::set_thread_width_share(usize::MAX);
            let cfg = AcceleratorConfig::engn();
            let prepared = PreparedGraph::from_arc(g);
            SimSession::new(&cfg, &prepared, &model)
                .run_traced("OBS")
                .1
                .to_chrome_json()
                .to_string_pretty()
        })
        .join()
        .expect("width-1 run")
    };
    assert_eq!(wide_a, narrow, "width-1 trace differs from the wide one");

    // Same pin through the scale-out path (chips fan out too).
    let parts = PartitionedGraph::build(g.clone(), PartitionerKind::Degree, 4);
    let wide = MultiChipSession::new(&cfg, &parts, &model)
        .run_traced("OBS")
        .1
        .to_chrome_json()
        .to_string_pretty();
    let narrow = {
        let g = g.clone();
        let model = model.clone();
        std::thread::spawn(move || {
            pool::set_thread_width_share(usize::MAX);
            let cfg = AcceleratorConfig::engn();
            let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 4);
            MultiChipSession::new(&cfg, &parts, &model)
                .run_traced("OBS")
                .1
                .to_chrome_json()
                .to_string_pretty()
        })
        .join()
        .expect("width-1 scale-out run")
    };
    assert_eq!(wide, narrow, "width-1 scale-out trace differs from the wide one");
}

/// The Chrome export parses with the crate's own JSON parser and holds
/// the full hierarchy: thread-name metadata first, then complete events
/// in `layer`/`stage`/`tile` categories; a spilling config adds `mem`
/// spans; the K = 4 trace adds `chipN/…` tracks and `comm` halo spans.
#[test]
fn chrome_json_is_valid_and_carries_the_span_hierarchy() {
    let (g, model) = workload();
    let mut cfg = AcceleratorConfig::engn();
    // Cap tier 0 below the working set so the trace gets `mem` spans.
    cfg.mem.name = "tiny";
    cfg.mem.tiers[0].capacity_bytes = 256.0 * 1024.0;
    let prepared = PreparedGraph::from_arc(g.clone());
    let (report, trace) = SimSession::new(&cfg, &prepared, &model).run_traced("OBS");
    assert!(report.spilled_bytes() > 0.0, "tiny tier 0 must spill");

    let rendered = trace.to_chrome_json().to_string_pretty();
    let doc = json::parse(&rendered).expect("chrome trace must parse");
    let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty());
    let phase = |e: &json::Json| e.get("ph").and_then(|p| p.as_str()).unwrap_or("").to_string();
    let cat = |e: &json::Json| e.get("cat").and_then(|c| c.as_str()).unwrap_or("").to_string();
    // Metadata events lead (one per track), then only complete events.
    let metas = events.iter().take_while(|e| phase(e) == "M").count();
    assert_eq!(metas, trace.tracks().len());
    assert!(events.iter().skip(metas).all(|e| phase(e) == "X"));
    for want in ["layer", "stage", "tile", "mem"] {
        assert!(
            events.iter().any(|e| cat(e) == want),
            "no {want:?} span in the single-chip trace"
        );
    }
    let clock = doc
        .get("otherData")
        .and_then(|o| o.get("clock"))
        .and_then(|c| c.as_str())
        .expect("otherData.clock");
    assert_eq!(clock, "sim-cycles");

    // Scale-out: per-chip tracks plus the halo-exchange comm spans.
    let cfg = AcceleratorConfig::engn();
    let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 4);
    let (_, trace) = MultiChipSession::new(&cfg, &parts, &model).run_traced("OBS");
    for c in 0..4 {
        let prefix = format!("chip{c}/");
        assert!(
            trace.tracks().iter().any(|t| t.starts_with(&prefix)),
            "no {prefix}* track in the K=4 trace"
        );
    }
    assert!(trace.spans().iter().any(|s| s.cat == "comm"), "no halo span in the K=4 trace");
    json::parse(&trace.to_chrome_json().to_string_pretty()).expect("K=4 trace must parse");
}

/// Counter/report consistency: the projections `engn run` makes into
/// the registry agree with the report fields they came from — spill
/// bytes per tier sum to `spilled_bytes()`, the halo-bytes counter is
/// exactly `comm_bytes`, and per-link bytes cover the ring.
#[test]
fn recorded_counters_agree_with_report_fields() {
    let (g, model) = workload();
    let mut cfg = AcceleratorConfig::engn();
    cfg.mem.name = "tiny";
    cfg.mem.tiers[0].capacity_bytes = 256.0 * 1024.0;
    let prepared = PreparedGraph::from_arc(g.clone());
    let session = SimSession::new(&cfg, &prepared, &model);
    let plans = session.plan();
    let report = session.run("OBS");
    assert!(report.spilled_bytes() > 0.0);

    let reg = obs::Registry::new();
    obs::record_sim(&reg, &report, &plans);
    let dump = reg.snapshot();
    let spill_sum: f64 = dump
        .counters
        .iter()
        .filter(|(k, _)| k.starts_with("engn_sim_spill_bytes_total"))
        .map(|(_, v)| v)
        .sum();
    let rel = (spill_sum - report.spilled_bytes()).abs() / report.spilled_bytes();
    assert!(rel < 1e-9, "spill counters {spill_sum} vs report {}", report.spilled_bytes());
    assert!((dump.counter("engn_sim_cycles_total") - report.total_cycles()).abs() < 1e-6);
    let stages = obs::stage_cycle_totals(&report);
    for (stage, want) in ["feature-extract", "aggregate", "update"].iter().zip(stages) {
        let got = dump.counter(&format!("engn_sim_stage_cycles_total{{stage=\"{stage}\"}}"));
        assert!((got - want).abs() < 1e-6, "{stage}: {got} vs {want}");
    }

    let cfg = AcceleratorConfig::engn();
    let parts = PartitionedGraph::build(g, PartitionerKind::Degree, 4);
    let session = MultiChipSession::new(&cfg, &parts, &model);
    let report = session.run("OBS");
    assert!(report.comm_bytes > 0.0);
    let agg_dims: Vec<usize> = session.plan_chip(0).iter().map(|p| p.agg_dim).collect();
    let links = session.per_link_bytes(&agg_dims);
    assert!(!links.is_empty());

    let reg = obs::Registry::new();
    obs::record_scaleout(&reg, &report, &links);
    let dump = reg.snapshot();
    assert_eq!(dump.counter("engn_scaleout_halo_bytes_total"), report.comm_bytes);
    assert_eq!(dump.counter("engn_scaleout_halo_vertices_total"), report.halo_vertices as f64);
    assert_eq!(dump.counter("engn_scaleout_comm_charged_cycles_total"), report.comm_cycles());
    // Every recorded link counter comes from the per-link table.
    for (link, bytes) in links.iter().filter(|(_, b)| *b > 0.0) {
        let got = dump.counter(&format!("engn_scaleout_link_bytes_total{{link=\"{link}\"}}"));
        assert_eq!(got, *bytes, "link {link}");
    }
}
