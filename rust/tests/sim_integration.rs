//! Cross-module integration: simulator vs model accounting, experiment
//! harness sanity at reduced scale, paper-shape assertions that tie the
//! whole system together.

use engn::baselines::cpu::{CpuModel, Framework};
use engn::baselines::gpu::GpuModel;
use engn::baselines::hygcn::HygcnModel;
use engn::baselines::Workload;
use engn::config::{AcceleratorConfig, DataflowKind, Fidelity};
use engn::graph::datasets::{self, ScalePolicy};
use engn::graph::rmat::{self, RmatParams};
use engn::model::{GnnKind, GnnModel};
use engn::report::experiments::{self, Eval};
use engn::sim::{PreparedGraph, SimReport, SimSession, Simulator};
use engn::util::geomean;

fn eval() -> Eval {
    Eval::new(ScalePolicy::Factor(128), 0xBEEF)
}

/// The headline claim, at reduced scale: EnGN beats every baseline on
/// every (model, dataset) pair of the paper's suite, and HyGCN sits
/// between GPUs and EnGN on average.
#[test]
fn engn_wins_across_the_suite() {
    let eval = eval();
    let mut vs_hygcn = Vec::new();
    for (kind, spec) in eval.suite() {
        let p = eval.pair(kind, &spec);
        let engn_s = p.engn.seconds();
        assert!(
            p.cpu_dgl.seconds() > engn_s,
            "{} {}: CPU-DGL {} <= EnGN {}",
            kind.name(),
            spec.code,
            p.cpu_dgl.seconds(),
            engn_s
        );
        if !p.gpu_dgl.oom {
            assert!(
                p.gpu_dgl.seconds() > engn_s * 0.8,
                "{} {}: GPU-DGL {} unexpectedly below EnGN {}",
                kind.name(),
                spec.code,
                p.gpu_dgl.seconds(),
                engn_s
            );
        }
        vs_hygcn.push(p.hygcn.seconds() / engn_s);
    }
    let hygcn_geo = geomean(&vs_hygcn);
    assert!(
        hygcn_geo > 1.2 && hygcn_geo < 20.0,
        "EnGN vs HyGCN geomean {hygcn_geo} out of the paper's ballpark (2.97x)"
    );
}

/// Energy-efficiency ordering (Fig 11): EnGN > HyGCN > GPU > CPU.
#[test]
fn energy_efficiency_ordering() {
    let eval = eval();
    let spec = datasets::by_code("PB").unwrap();
    let p = eval.pair(GnnKind::Gcn, &spec);
    let engn = p.engn.gops_per_watt();
    let hygcn = p.hygcn.gops_per_watt();
    let gpu = p.gpu_dgl.gops_per_watt();
    let cpu = p.cpu_dgl.gops_per_watt();
    assert!(engn > hygcn, "EnGN {engn} <= HyGCN {hygcn}");
    assert!(hygcn > gpu, "HyGCN {hygcn} <= GPU {gpu}");
    assert!(gpu > cpu, "GPU {gpu} <= CPU {cpu}");
}

/// Cycle and Phase fidelity agree (they only differ via sampling, which
/// the capped suite does not trigger; this guards the invariant).
#[test]
fn fidelity_modes_agree_at_capped_scale() {
    let spec = datasets::by_code("CA").unwrap();
    let g = spec.instantiate(ScalePolicy::Capped, 5);
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let mut cfg = AcceleratorConfig::engn();
    cfg.fidelity = Fidelity::Cycle;
    let cycle = Simulator::new(cfg.clone()).run(&model, &g, "CA");
    cfg.fidelity = Fidelity::Phase;
    let phase = Simulator::new(cfg).run(&model, &g, "CA");
    let rel = (cycle.total_cycles() - phase.total_cycles()).abs() / cycle.total_cycles();
    assert!(rel < 0.05, "fidelity divergence {rel}");
}

/// The simulator's op accounting must equal the descriptor model's ops
/// for every architecture (not just GCN).
#[test]
fn ops_match_descriptors_for_all_models() {
    for (kind, code) in [
        (GnnKind::Gcn, "PB"),
        (GnnKind::GsPool, "RD"),
        (GnnKind::GatedGcn, "SA"),
        (GnnKind::Grn, "SC"),
        (GnnKind::Rgcn, "AF"),
    ] {
        let spec = datasets::by_code(code).unwrap();
        let g = spec.instantiate(ScalePolicy::Factor(128), 3);
        let model = GnnModel::for_dataset(kind, &spec);
        let r = Simulator::new(AcceleratorConfig::engn()).run(&model, &g, code);
        let hist = engn::model::ops::relation_histogram(
            &g.relations,
            g.num_relations,
            g.num_edges(),
        );
        let expected: f64 = engn::model::ops::model_ops(
            &model,
            g.num_vertices,
            g.num_edges(),
            &hist,
            |l| engn::model::ops::dasr_order(&model, l),
        )
        .iter()
        .map(|o| o.total())
        .sum();
        let rel = (r.total_ops() - expected).abs() / expected;
        assert!(rel < 1e-9, "{} {code}: ops mismatch {rel}", kind.name());
    }
}

/// Preparation reuse must be invisible to results: a report produced
/// through a shared `PreparedGraph` (twice, so the second run hits the
/// tiling cache) is bit-identical to a fresh `Simulator::run` that
/// prepares its own state.
#[test]
fn prepared_session_bit_identical_to_fresh_run() {
    let spec = datasets::by_code("PB").unwrap();
    let g = std::sync::Arc::new(spec.instantiate(ScalePolicy::Capped, 21));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    let fresh = Simulator::new(cfg.clone()).run(&model, &g, "PB");
    let prepared = PreparedGraph::from_arc(g.clone());
    let session = SimSession::new(&cfg, &prepared, &model);
    let first = session.run("PB");
    let reused = session.run("PB");
    for r in [&first, &reused] {
        assert_eq!(r.total_cycles(), fresh.total_cycles());
        assert_eq!(r.total_ops(), fresh.total_ops());
        assert_eq!(r.chip_energy_j, fresh.chip_energy_j);
        assert_eq!(r.hbm_energy_j, fresh.hbm_energy_j);
        assert_eq!(r.power_w, fresh.power_w);
        assert_eq!(r.traffic().hbm_read_bytes, fresh.traffic().hbm_read_bytes);
        assert_eq!(r.traffic().hbm_write_bytes, fresh.traffic().hbm_write_bytes);
        assert_eq!(r.davc().accesses, fresh.davc().accesses);
        assert_eq!(r.davc().hits, fresh.davc().hits);
        assert_eq!(r.layers.len(), fresh.layers.len());
        for (a, b) in r.layers.iter().zip(fresh.layers.iter()) {
            assert_eq!(a.q, b.q);
            assert_eq!(a.aggregate.cycles, b.aggregate.cycles);
            assert_eq!(a.total_cycles, b.total_cycles);
        }
    }
}

/// The dense-systolic baseline dataflow must never beat RER on a
/// power-law graph: its interval-shaped aggregation and unbounded
/// interval streaming are exactly the locality gap EnGN closes.
#[test]
fn dense_systolic_no_faster_than_rer_on_power_law() {
    let g = rmat::generate(20_000, 120_000, RmatParams::default(), 13);
    let spec = datasets::by_code("PB").unwrap();
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let prepared = PreparedGraph::from_arc(std::sync::Arc::new(g));
    let rer_cfg = AcceleratorConfig::engn();
    let dense_cfg = AcceleratorConfig::engn()
        .with_dataflow(DataflowKind::DenseSystolic)
        .named("EnGN_densesys");
    let rer = SimSession::new(&rer_cfg, &prepared, &model).run("SY");
    let dense = SimSession::new(&dense_cfg, &prepared, &model).run("SY");
    assert!(
        dense.total_cycles() >= rer.total_cycles(),
        "dense {} < rer {}",
        dense.total_cycles(),
        rer.total_cycles()
    );
    let agg = |r: &SimReport| r.layers.iter().map(|l| l.aggregate.cycles).sum::<f64>();
    assert!(
        agg(&dense) > agg(&rer),
        "dense aggregation {} should strictly exceed RER {} on sparse tiles",
        agg(&dense),
        agg(&rer)
    );
    // No vertex cache in the dense baseline; RER's DAVC sees traffic.
    assert_eq!(dense.davc().accesses, 0);
    assert!(rer.davc().accesses > 0);
    // Unbounded interval streaming costs at least as much HBM traffic.
    assert!(dense.traffic().hbm_total() >= rer.traffic().hbm_total());
}

/// Baselines respond to workload scale monotonically (sanity for the
/// analytic models).
#[test]
fn baselines_scale_monotonically() {
    let spec = datasets::by_code("PB").unwrap();
    let m = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let small = Workload::new(10_000, 50_000);
    let large = Workload::new(100_000, 500_000);
    for seconds in [
        |w: &Workload, m: &GnnModel| CpuModel::new(Framework::Dgl).run(m, w).seconds(),
        |w: &Workload, m: &GnnModel| GpuModel::new(Framework::Dgl).run(m, w).seconds(),
        |w: &Workload, m: &GnnModel| HygcnModel::paper().run(m, w).seconds(),
    ] {
        assert!(seconds(&large, &m) > seconds(&small, &m));
    }
}

/// Every experiment renders, has content, and round-trips through CSV.
#[test]
fn all_experiments_render_at_small_scale() {
    let eval = Eval::new(ScalePolicy::Factor(512), 11);
    for id in experiments::ALL_IDS {
        let t = experiments::by_id(&eval, id).unwrap_or_else(|| panic!("missing {id}"));
        assert!(!t.rows.is_empty(), "{id} has no rows");
        let rendered = t.render();
        assert!(rendered.contains(&t.id), "{id} render");
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), t.rows.len() + 1, "{id} csv");
    }
}

/// EnGN's per-configuration scaling (Fig 17's shape): more rows help,
/// 32 columns do not when output dims are 16.
#[test]
fn pe_array_scaling_shape() {
    let spec = datasets::by_code("PB").unwrap();
    let g = spec.instantiate(ScalePolicy::Capped, 9);
    let m = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let gops = |rows: usize, cols: usize| {
        Simulator::new(AcceleratorConfig::with_array(rows, cols))
            .run(&m, &g, "PB")
            .gops()
    };
    let g32 = gops(32, 16);
    let g128 = gops(128, 16);
    assert!(g128 > g32, "rows should scale: {g128} vs {g32}");
    let g32x32 = gops(32, 32);
    assert!(
        g32x32 < g32 * 1.15,
        "extra columns should not help at hidden=16: {g32x32} vs {g32}"
    );
}
