//! Parallel-engine integration: the counting-sort tiling is pinned
//! bit-identical to the comparison-sort reference over random graphs,
//! and every parallel fan-out (config sweep, session layers, serving
//! sim batches) is pinned bit-identical to serial execution. CI runs
//! this file with `--test-threads 1` and the default harness width to
//! catch order-dependence (see .github/workflows/ci.yml).

use engn::config::AcceleratorConfig;
use engn::coordinator::{Backend, JobPayload, SimBackend, SimJob};
use engn::graph::datasets::{self, ScalePolicy};
use engn::graph::rmat::{self, RmatParams};
use engn::model::{GnnKind, GnnModel};
use engn::sim::{sweep_with, EdgeTiling, PreparedGraph, SimReport, SimSession};
use engn::util::ceil_div;
use engn::util::prop::prop_check;
use std::sync::Arc;

fn tilings_identical(a: &EdgeTiling, b: &EdgeTiling) -> Result<(), String> {
    if a.q != b.q || a.span != b.span {
        return Err(format!("shape mismatch: q {} vs {}, span {} vs {}", a.q, b.q, a.span, b.span));
    }
    if a.num_tiles() != b.num_tiles() {
        return Err(format!("tile count {} vs {}", a.num_tiles(), b.num_tiles()));
    }
    if a.src_touched() != b.src_touched() || a.dst_touched() != b.dst_touched() {
        return Err(format!(
            "touched sums differ: src {} vs {}, dst {} vs {}",
            a.src_touched(),
            b.src_touched(),
            a.dst_touched(),
            b.dst_touched()
        ));
    }
    for (ta, tb) in a.runs().zip(b.runs()) {
        if (ta.row, ta.col) != (tb.row, tb.col) {
            return Err(format!(
                "tile key mismatch: ({},{}) vs ({},{})",
                ta.row, ta.col, tb.row, tb.col
            ));
        }
        if ta.edges != tb.edges {
            return Err(format!(
                "tile ({},{}) edges differ (count {} vs {}, or order within tile)",
                ta.row,
                ta.col,
                ta.edges.len(),
                tb.edges.len()
            ));
        }
        if ta.distinct_src != tb.distinct_src || ta.distinct_dst != tb.distinct_dst {
            return Err(format!(
                "tile ({},{}) distinct counts differ: src {} vs {}, dst {} vs {}",
                ta.row, ta.col, ta.distinct_src, tb.distinct_src, ta.distinct_dst, tb.distinct_dst
            ));
        }
    }
    Ok(())
}

/// Property: over seeded R-MAT graphs and random Q, the O(E + Q²)
/// counting-sort build is bit-identical to the stable comparison-sort
/// reference — edges per tile, order within tile, distinct counts, and
/// the src/dst touched sums.
#[test]
fn prop_counting_sort_tiling_matches_reference() {
    prop_check(30, 0x7117_0002, |rng| {
        let n = rng.gen_usize(8, 600);
        let e = rng.gen_usize(1, 5 * n);
        let q = rng.gen_usize(1, 14);
        let g = rmat::generate(n, e, RmatParams::default(), rng.next_u64());
        let span = ceil_div(n.max(1), q);
        tilings_identical(
            &EdgeTiling::build(&g.edges, span, q),
            &EdgeTiling::build_reference(&g.edges, span, q),
        )
    });
}

/// The same pin at realistic scale, over several fixed Q values
/// (including Q = 1 and a Q that leaves the last interval ragged).
#[test]
fn counting_sort_tiling_matches_reference_at_fixed_qs() {
    let g = rmat::generate(9_000, 70_000, RmatParams::default(), 0xE16A);
    for q in [1usize, 2, 7, 16, 33, 100] {
        let span = ceil_div(9_000, q);
        tilings_identical(
            &EdgeTiling::build(&g.edges, span, q),
            &EdgeTiling::build_reference(&g.edges, span, q),
        )
        .unwrap_or_else(|msg| panic!("Q={q}: {msg}"));
    }
}

fn assert_reports_identical(a: &SimReport, b: &SimReport) {
    assert_eq!(a.config_name, b.config_name);
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_ops(), b.total_ops());
    assert_eq!(a.chip_energy_j, b.chip_energy_j);
    assert_eq!(a.hbm_energy_j, b.hbm_energy_j);
    assert_eq!(a.power_w, b.power_w);
    assert_eq!(a.traffic().hbm_read_bytes, b.traffic().hbm_read_bytes);
    assert_eq!(a.traffic().hbm_write_bytes, b.traffic().hbm_write_bytes);
    assert_eq!(a.davc().accesses, b.davc().accesses);
    assert_eq!(a.davc().hits, b.davc().hits);
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
        assert_eq!(la.layer_idx, lb.layer_idx);
        assert_eq!(la.q, lb.q);
        assert_eq!(la.aggregate.cycles, lb.aggregate.cycles);
        assert_eq!(la.feature_extraction.cycles, lb.feature_extraction.cycles);
        assert_eq!(la.update.cycles, lb.update.cycles);
        assert_eq!(la.total_cycles, lb.total_cycles);
    }
}

fn sweep_variants() -> Vec<AcceleratorConfig> {
    let mut v = vec![
        AcceleratorConfig::engn(),
        AcceleratorConfig::with_array(32, 16),
        AcceleratorConfig::with_array(64, 16),
        AcceleratorConfig::engn_22mb(),
    ];
    let mut davc = AcceleratorConfig::engn().named("EnGN_davc16K");
    davc.davc_bytes = 16 * 1024;
    v.push(davc);
    v
}

/// Determinism: a parallel design-space sweep's `SimReport`s are
/// bit-identical to the serial run — outputs are collected by
/// configuration index, never completion order.
#[test]
fn parallel_sweep_bit_identical_to_serial() {
    let spec = datasets::by_code("PB").unwrap();
    let prepared =
        PreparedGraph::from_arc(Arc::new(spec.instantiate(ScalePolicy::Factor(64), 9)));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let variants = sweep_variants();
    let serial = sweep_with(1, &variants, &prepared, &model, "PB");
    let parallel = sweep_with(8, &variants, &prepared, &model, "PB");
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.iter().zip(&parallel) {
        assert_reports_identical(a, b);
    }
}

/// Determinism through the serving plane: a sim batch fanned out by the
/// backend answers bit-identically to sessions run serially by hand.
#[test]
fn sim_backend_parallel_batch_matches_serial_sessions() {
    let be = SimBackend::new();
    let jobs: Vec<JobPayload> = sweep_variants()
        .into_iter()
        .map(|cfg| JobPayload::Sim(SimJob::new(GnnKind::Gcn, "CA").with_config(cfg)))
        .collect();
    let results = be.execute_batch(jobs.clone());
    assert_eq!(results.len(), jobs.len());

    // Serial ground truth: same dataset instantiation (SimJob's default
    // policy and seed), same prepared graph, one session per config.
    let spec = datasets::by_code("CA").unwrap();
    let prepared =
        PreparedGraph::from_arc(Arc::new(spec.instantiate(ScalePolicy::Capped, 0xE16A)));
    for (job, result) in jobs.iter().zip(&results) {
        let JobPayload::Sim(j) = job else { panic!("sim job") };
        let model = GnnModel::for_dataset(j.model, &spec);
        let want = SimSession::new(&j.config, &prepared, &model).run(spec.code);
        let got = result.as_ref().expect("sim ok").as_sim().expect("sim output");
        assert_eq!(got.config, j.config.name);
        assert_eq!(got.cycles, want.total_cycles());
        assert_eq!(got.seconds, want.seconds());
        assert_eq!(got.energy_j, want.energy_j());
        assert_eq!(got.power_w, want.power_w);
        assert_eq!(got.gops, want.gops());
    }
}

/// A session's per-layer parallel execution is invisible in the report:
/// two runs of the same session (layers fanned out across the pool,
/// tiling cache warm on the second) are bit-identical.
#[test]
fn repeated_parallel_session_runs_are_bit_identical() {
    let spec = datasets::by_code("NE").unwrap();
    let prepared =
        PreparedGraph::from_arc(Arc::new(spec.instantiate(ScalePolicy::Factor(128), 5)));
    let model = GnnModel::for_dataset(GnnKind::Gcn, &spec);
    let cfg = AcceleratorConfig::engn();
    let session = SimSession::new(&cfg, &prepared, &model);
    let first = session.run("NE");
    let second = session.run("NE");
    assert_reports_identical(&first, &second);
}
