#!/usr/bin/env bash
# Snapshot the hotpath micro-bench medians into BENCH_hotpath.json at
# the repository root, giving future PRs a perf trajectory to compare
# against (group name -> median nanoseconds).
#
#   scripts/bench_snapshot.sh [extra cargo-bench args...]
#
# The JSON is written by the bench binary itself (BENCH_JSON env var),
# so the numbers are exactly the medians it printed — no log scraping.
set -euo pipefail
cd "$(dirname "$0")/.."
BENCH_JSON="$(pwd)/BENCH_hotpath.json" \
  cargo bench --manifest-path rust/Cargo.toml --bench hotpath "$@"
# The snapshot must track the scale-out, dataflow and out-of-core
# planes: fail loudly if the partition/scaleout/dataflow/mem/csr groups
# ever drop out of the hotpath bench.
for group in "partition:range" "partition:hash" "partition:degree" "scaleout:4chip" \
             "dataflow:spmm" "dataflow:hash" "dataflow:adaptive" \
             "mem:spill" "csr:open"; do
  grep -q "\"$group\"" BENCH_hotpath.json \
    || { echo "missing bench group $group in BENCH_hotpath.json" >&2; exit 1; }
done
echo "snapshot: $(pwd)/BENCH_hotpath.json"
