#!/usr/bin/env bash
# Snapshot the hotpath micro-bench medians into BENCH_hotpath.json at
# the repository root, giving future PRs a perf trajectory to compare
# against (group name -> median nanoseconds).
#
#   scripts/bench_snapshot.sh [extra cargo-bench args...]
#
# The JSON is written by the bench binary itself (BENCH_JSON env var),
# so the numbers are exactly the medians it printed — no log scraping.
set -euo pipefail
cd "$(dirname "$0")/.."
BENCH_JSON="$(pwd)/BENCH_hotpath.json" \
  cargo bench --manifest-path rust/Cargo.toml --bench hotpath "$@"
# The snapshot must track the scale-out, dataflow and out-of-core
# planes: fail loudly if the partition/scaleout/dataflow/mem/csr groups
# ever drop out of the hotpath bench.
for group in "partition:range" "partition:hash" "partition:degree" \
             "partition:ldg" "partition:fennel" \
             "scaleout:4chip" "scaleout:overlap" \
             "dataflow:spmm" "dataflow:hash" "dataflow:adaptive" \
             "mem:spill" "csr:open"; do
  grep -q "\"$group\"" BENCH_hotpath.json \
    || { echo "missing bench group $group in BENCH_hotpath.json" >&2; exit 1; }
done
echo "snapshot: $(pwd)/BENCH_hotpath.json"

# Serving saturation sweep: `engn loadgen --sweep` steps the offered
# rate over fresh services until the shed rate crosses the threshold
# and writes BENCH_serving.json itself (per-priority p99s at the knee
# plus every rung's full report). Gate the per-class groups the same
# way as the hotpath groups above.
cargo run --release --manifest-path rust/Cargo.toml -- \
  loadgen --sweep --rate 100 --requests 120 --workers 2 \
  --sweep-steps 4 --sweep-factor 3 --sweep-threshold 0.3 \
  --out "$(pwd)/BENCH_serving.json"
for group in "serving:saturation_rps" "serving:interactive:p99_s" \
             "serving:batch:p99_s" "serving:best_effort:p99_s"; do
  grep -q "\"$group\"" BENCH_serving.json \
    || { echo "missing serving group $group in BENCH_serving.json" >&2; exit 1; }
done
echo "snapshot: $(pwd)/BENCH_serving.json"
