#!/usr/bin/env bash
# Snapshot the hotpath micro-bench medians into BENCH_hotpath.json at
# the repository root, giving future PRs a perf trajectory to compare
# against (group name -> median nanoseconds).
#
#   scripts/bench_snapshot.sh [extra cargo-bench args...]
#
# The JSON is written by the bench binary itself (BENCH_JSON env var),
# so the numbers are exactly the medians it printed — no log scraping.
# Each run is validated in a temp file and only then moved over the
# committed snapshot: a broken toolchain or a bench that dropped a
# group can never clobber real numbers with a placeholder. Validated
# snapshots are stamped with host metadata (cores, git sha, UTC
# timestamp) so a trajectory across machines stays interpretable.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  cat >&2 <<'EOF'
!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!
!! bench_snapshot.sh: no Rust toolchain on this host (cargo not   !!
!! found). Refusing to run: the committed BENCH_*.json snapshots  !!
!! are left untouched. Run this script on a quiet multicore host  !!
!! with the rust toolchain installed.                             !!
!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!!
EOF
  exit 1
fi

# True iff the file holds measured groups (the seed placeholder carries
# only a "_note" asking to be populated).
is_real_snapshot() {
  [ -f "$1" ] && grep -q '":' "$1" && ! grep -q '"_note".*populate' "$1"
}

# Validate a candidate snapshot: parseable JSON carrying every required
# group. Aborts (leaving the committed file untouched) on any miss.
check_groups() {
  local file=$1
  shift
  python3 -m json.tool "$file" >/dev/null \
    || { echo "bench_snapshot.sh: $file is not valid JSON" >&2; exit 1; }
  for group in "$@"; do
    grep -q "\"$group\"" "$file" \
      || { echo "missing bench group $group in $file" >&2; exit 1; }
  done
}

# Stamp host metadata into a validated snapshot (top-level "_host" key)
# and move it over the committed file.
install_snapshot() {
  local tmp=$1 dest=$2
  python3 - "$tmp" <<'EOF'
import json, os, subprocess, sys, time
path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)
sha = "unknown"
try:
    sha = subprocess.run(
        ["git", "rev-parse", "--short", "HEAD"],
        capture_output=True, text=True, check=True,
    ).stdout.strip()
except Exception:
    pass
doc["_host"] = {
    "cores": os.cpu_count(),
    "git_sha": sha,
    "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
}
with open(path, "w") as f:
    json.dump(doc, f, indent=2, sort_keys=True)
    f.write("\n")
EOF
  mv "$tmp" "$dest"
  echo "snapshot: $(pwd)/$dest"
}

# The snapshot must track the scale-out, dataflow, out-of-core and
# observability planes: fail loudly if the partition/scaleout/dataflow/
# mem/csr/obs groups ever drop out of the hotpath bench.
HOTPATH_GROUPS=(
  "partition:range" "partition:hash" "partition:degree"
  "partition:ldg" "partition:fennel"
  "scaleout:4chip" "scaleout:overlap"
  "dataflow:spmm" "dataflow:hash" "dataflow:adaptive"
  "mem:spill" "csr:open" "obs:trace"
)
tmp=BENCH_hotpath.json.tmp
trap 'rm -f BENCH_hotpath.json.tmp BENCH_serving.json.tmp' EXIT
BENCH_JSON="$(pwd)/$tmp" \
  cargo bench --manifest-path rust/Cargo.toml --bench hotpath "$@"
if ! is_real_snapshot "$tmp"; then
  echo "bench_snapshot.sh: bench run produced no measured groups;" \
       "refusing to overwrite BENCH_hotpath.json" >&2
  exit 1
fi
check_groups "$tmp" "${HOTPATH_GROUPS[@]}"
install_snapshot "$tmp" BENCH_hotpath.json

# Serving saturation sweep: `engn loadgen --sweep` steps the offered
# rate over fresh services until the shed rate crosses the threshold
# and writes BENCH_serving.json itself (per-priority p99s at the knee
# plus every rung's full report). Gate the per-class groups the same
# way as the hotpath groups above.
SERVING_GROUPS=(
  "serving:saturation_rps" "serving:interactive:p99_s"
  "serving:batch:p99_s" "serving:best_effort:p99_s"
)
tmp=BENCH_serving.json.tmp
cargo run --release --manifest-path rust/Cargo.toml -- \
  loadgen --sweep --rate 100 --requests 120 --workers 2 \
  --sweep-steps 4 --sweep-factor 3 --sweep-threshold 0.3 \
  --out "$(pwd)/$tmp"
if ! is_real_snapshot "$tmp"; then
  echo "bench_snapshot.sh: sweep produced no measured groups;" \
       "refusing to overwrite BENCH_serving.json" >&2
  exit 1
fi
check_groups "$tmp" "${SERVING_GROUPS[@]}"
install_snapshot "$tmp" BENCH_serving.json
